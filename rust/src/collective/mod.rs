//! Collective operations (MPI-4.0 §6): blocking and nonblocking variants
//! of barrier, bcast, gather(v), scatter(v), allgather(v), alltoall(v,w),
//! reduce, allreduce, reduce_scatter(+_block), scan and exscan — all
//! expressed as round-based schedules over the p2p engine (see
//! [`schedule`]), so the `i*` variants are the same code wrapped in a
//! request.
//!
//! Algorithm choice is a first-class tuning surface: the entry points
//! below resolve the process-global knobs in [`config`] — `auto` by
//! default — through the topology-aware decision tables in [`tuned`]
//! *before* building the schedule, so every caller (blocking,
//! nonblocking, persistent, and the modern futures/pipelines on top)
//! gets a size- and shape-appropriate algorithm without asking.
//! Persistent templates therefore capture the resolved algorithm at
//! init time; [`PersistentColl::algorithm`] reports it.

pub mod builders;
pub mod combine;
pub mod config;
pub mod persistent;
pub mod schedule;
pub mod tuned;

pub use config::{AllgathervAlg, AllreduceAlg, AlltoallvAlg, BcastAlg, ReduceAlg};
pub use persistent::PersistentColl;

use crate::comm::Comm;
use crate::datatype::Datatype;
use crate::op::Op;
use crate::request::Request;
use crate::Result;
use schedule::{run_blocking, run_nonblocking, CollState, Schedule};
use std::collections::VecDeque;
use std::rc::Rc;
use std::sync::atomic::Ordering;
use tuned::ChunkPlan;

fn state(
    comm: &Comm,
    dtype: &Datatype,
    op: Option<Op>,
    sched: Schedule,
    name: &'static str,
    alg: &'static str,
) -> Rc<CollState> {
    CollState::new(
        comm.rank_ctx().clone(),
        comm.ctx_coll(),
        comm.group().clone(),
        dtype.clone(),
        op,
        sched,
        name,
        alg,
    )
}

fn byte() -> Datatype {
    Datatype::primitive(crate::datatype::Primitive::Byte)
}

/// Uniform byte displacements `i * count * extent` used to lower the
/// non-v collectives onto the v builders.
fn uniform(comm: &Comm, count: usize, dtype: &Datatype) -> (Vec<usize>, Vec<usize>) {
    let p = comm.size();
    let stride = count * dtype.extent() as usize;
    ((0..p).map(|_| count).collect(), (0..p).map(|i| i * stride).collect())
}

// ---------------- chunked reduction pipeline ----------------

/// Max concurrently in-flight chunk schedules in the blocking chunked
/// pipeline. Bounds arena memory to `CHUNK_WINDOW` chunk-sized schedules
/// while still letting chunk `c`'s combine overlap chunk `c+1`'s
/// transfer.
const CHUNK_WINDOW: usize = 4;

/// Drive `nchunks` per-chunk schedules through a bounded in-flight
/// window. Every rank issues chunks in ascending order, so the per-chunk
/// collective sequence numbers (and hence tag spaces) line up across the
/// job; waiting drives the whole engine, so a blocked oldest chunk still
/// progresses the younger ones — that concurrency *is* the overlap.
fn run_chunked<F>(comm: &Comm, nchunks: usize, mut issue: F) -> Result<()>
where
    F: FnMut(usize) -> Result<Request>,
{
    let stats = &comm.rank_ctx().fabric.stats;
    let mut inflight: VecDeque<Request> = VecDeque::new();
    for c in 0..nchunks {
        inflight.push_back(issue(c)?);
        stats.chunks_inflight_max.fetch_max(inflight.len() as u64, Ordering::Relaxed);
        if inflight.len() >= CHUNK_WINDOW {
            inflight.pop_front().unwrap().wait()?;
        }
    }
    for r in inflight {
        r.wait()?;
    }
    Ok(())
}

/// The chunked allreduce body: split the element range into the plan's
/// chunks and run each as an independent pinned-algorithm allreduce over
/// disjoint buffer slices. Eligibility (contiguous uniform layout,
/// predefined commutative op, chunk-invariant algorithm) was already
/// established by [`tuned::resolve_allreduce_chunking`], which is what
/// makes this byte-identical to the unchunked fold.
fn allreduce_chunked(
    comm: &Comm,
    sbuf: Option<&[u8]>,
    rbuf: &mut [u8],
    count: usize,
    dtype: &Datatype,
    op: &Op,
    alg: AllreduceAlg,
    plan: ChunkPlan,
) -> Result<()> {
    let esz = dtype.size();
    run_chunked(comm, plan.nchunks, |c| {
        // Re-checked per chunk: every chunk is a full reduction schedule
        // of its own, so the RMA-only-op rejection fires for each.
        op.require_reduction()?;
        let base = c * plan.chunk_elems;
        let n = plan.chunk_elems.min(count - base);
        let sch = sbuf.map(|s| &s[base * esz..(base + n) * esz]);
        let rch = &mut rbuf[base * esz..(base + n) * esz];
        let sched = builders::allreduce(comm, sch, rch, n, dtype, op, alg);
        Ok(run_nonblocking(state(comm, dtype, Some(op.clone()), sched, "allreduce", alg.label())))
    })
}

/// The chunked rooted-reduce body (see [`allreduce_chunked`]).
fn reduce_chunked(
    comm: &Comm,
    sbuf: Option<&[u8]>,
    mut rbuf: Option<&mut [u8]>,
    count: usize,
    dtype: &Datatype,
    op: &Op,
    root: usize,
    alg: ReduceAlg,
    plan: ChunkPlan,
) -> Result<()> {
    let esz = dtype.size();
    run_chunked(comm, plan.nchunks, |c| {
        op.require_reduction()?;
        let base = c * plan.chunk_elems;
        let n = plan.chunk_elems.min(count - base);
        let sch = sbuf.map(|s| &s[base * esz..(base + n) * esz]);
        let rch = rbuf.as_deref_mut().map(|r| &mut r[base * esz..(base + n) * esz]);
        let sched = builders::reduce(comm, sch, rch, n, dtype, op, root, alg)?;
        Ok(run_nonblocking(state(comm, dtype, Some(op.clone()), sched, "reduce", alg.label())))
    })
}

// ---------------- barrier ----------------

/// `MPI_Barrier`.
pub fn barrier(comm: &Comm) -> Result<()> {
    let d = byte();
    run_blocking(state(comm, &d, None, builders::barrier(comm), "barrier", "dissemination"))
}

/// `MPI_Ibarrier`.
pub fn ibarrier(comm: &Comm) -> Result<Request> {
    let d = byte();
    Ok(run_nonblocking(state(comm, &d, None, builders::barrier(comm), "ibarrier", "dissemination")))
}

/// `MPI_Barrier_init` (MPI-4.0 §6.13): build the dissemination schedule
/// once; each `start()` re-runs it with no allocation.
pub fn barrier_init(comm: &Comm) -> Result<PersistentColl> {
    let d = byte();
    Ok(PersistentColl::new(state(comm, &d, None, builders::barrier(comm), "barrier", "dissemination")))
}

// ---------------- bcast ----------------

/// `MPI_Bcast`.
pub fn bcast(comm: &Comm, buf: &mut [u8], count: usize, dtype: &Datatype, root: usize) -> Result<()> {
    dtype.require_committed()?;
    let alg = tuned::resolve_bcast(comm, dtype.size() * count, config::bcast_alg());
    let sched = builders::bcast(comm, buf, count, dtype, root, alg);
    run_blocking(state(comm, dtype, None, sched, "bcast", alg.label()))
}

/// `MPI_Ibcast`.
pub fn ibcast(comm: &Comm, buf: &mut [u8], count: usize, dtype: &Datatype, root: usize) -> Result<Request> {
    dtype.require_committed()?;
    let alg = tuned::resolve_bcast(comm, dtype.size() * count, config::bcast_alg());
    let sched = builders::bcast(comm, buf, count, dtype, root, alg);
    Ok(run_nonblocking(state(comm, dtype, None, sched, "ibcast", alg.label())))
}

/// `MPI_Bcast_init`. The schedule captures `buf` by raw pointer: the
/// caller keeps the buffer alive and stable for the template's lifetime
/// (the standard's persistent-buffer contract) and refills it between
/// `start()`s; root re-packs, non-roots re-unpack on every execution.
pub fn bcast_init(comm: &Comm, buf: &mut [u8], count: usize, dtype: &Datatype, root: usize) -> Result<PersistentColl> {
    dtype.require_committed()?;
    let alg = tuned::resolve_bcast(comm, dtype.size() * count, config::bcast_alg());
    let sched = builders::bcast(comm, buf, count, dtype, root, alg);
    Ok(PersistentColl::new(state(comm, dtype, None, sched, "bcast", alg.label())))
}

// ---------------- reduce / allreduce ----------------

/// `MPI_Reduce`. `sbuf = None` is `MPI_IN_PLACE` (root's contribution is
/// in `rbuf`). Non-root ranks may pass `rbuf = None`.
pub fn reduce(
    comm: &Comm,
    sbuf: Option<&[u8]>,
    rbuf: Option<&mut [u8]>,
    count: usize,
    dtype: &Datatype,
    op: &Op,
    root: usize,
) -> Result<()> {
    dtype.require_committed()?;
    op.require_reduction()?;
    if let Some((alg, plan)) = tuned::resolve_reduce_chunking(comm, count, dtype, op) {
        return reduce_chunked(comm, sbuf, rbuf, count, dtype, op, root, alg, plan);
    }
    let bytes = dtype.size() * count;
    let alg = tuned::resolve_reduce(comm, bytes, op.is_commutative(), config::reduce_alg());
    let sched = builders::reduce(comm, sbuf, rbuf, count, dtype, op, root, alg)?;
    run_blocking(state(comm, dtype, Some(op.clone()), sched, "reduce", alg.label()))
}

/// `MPI_Ireduce`.
pub fn ireduce(
    comm: &Comm,
    sbuf: Option<&[u8]>,
    rbuf: Option<&mut [u8]>,
    count: usize,
    dtype: &Datatype,
    op: &Op,
    root: usize,
) -> Result<Request> {
    dtype.require_committed()?;
    op.require_reduction()?;
    let bytes = dtype.size() * count;
    let alg = tuned::resolve_reduce(comm, bytes, op.is_commutative(), config::reduce_alg());
    let sched = builders::reduce(comm, sbuf, rbuf, count, dtype, op, root, alg)?;
    Ok(run_nonblocking(state(comm, dtype, Some(op.clone()), sched, "ireduce", alg.label())))
}

/// `MPI_Allreduce`. `sbuf = None` is `MPI_IN_PLACE`.
pub fn allreduce(
    comm: &Comm,
    sbuf: Option<&[u8]>,
    rbuf: &mut [u8],
    count: usize,
    dtype: &Datatype,
    op: &Op,
) -> Result<()> {
    dtype.require_committed()?;
    op.require_reduction()?;
    if let Some((alg, plan)) = tuned::resolve_allreduce_chunking(comm, count, dtype, op) {
        return allreduce_chunked(comm, sbuf, rbuf, count, dtype, op, alg, plan);
    }
    let bytes = dtype.size() * count;
    let alg = tuned::resolve_allreduce(comm, bytes, op.is_commutative(), config::allreduce_alg());
    let sched = builders::allreduce(comm, sbuf, rbuf, count, dtype, op, alg);
    run_blocking(state(comm, dtype, Some(op.clone()), sched, "allreduce", alg.label()))
}

/// `MPI_Iallreduce`.
pub fn iallreduce(
    comm: &Comm,
    sbuf: Option<&[u8]>,
    rbuf: &mut [u8],
    count: usize,
    dtype: &Datatype,
    op: &Op,
) -> Result<Request> {
    dtype.require_committed()?;
    op.require_reduction()?;
    let bytes = dtype.size() * count;
    let alg = tuned::resolve_allreduce(comm, bytes, op.is_commutative(), config::allreduce_alg());
    let sched = builders::allreduce(comm, sbuf, rbuf, count, dtype, op, alg);
    Ok(run_nonblocking(state(comm, dtype, Some(op.clone()), sched, "iallreduce", alg.label())))
}

/// `MPI_Allreduce_init`. Buffer contract as in [`bcast_init`]: both
/// buffers are captured by pointer for the template's lifetime; every
/// `start()` re-packs `sbuf` (or `rbuf` for IN_PLACE) and re-unpacks the
/// result into `rbuf`.
pub fn allreduce_init(
    comm: &Comm,
    sbuf: Option<&[u8]>,
    rbuf: &mut [u8],
    count: usize,
    dtype: &Datatype,
    op: &Op,
) -> Result<PersistentColl> {
    dtype.require_committed()?;
    op.require_reduction()?;
    let bytes = dtype.size() * count;
    let alg = tuned::resolve_allreduce(comm, bytes, op.is_commutative(), config::allreduce_alg());
    let sched = builders::allreduce(comm, sbuf, rbuf, count, dtype, op, alg);
    Ok(PersistentColl::new(state(comm, dtype, Some(op.clone()), sched, "allreduce", alg.label())))
}

/// [`allreduce_init`] with an explicitly pinned algorithm — the chunked
/// persistent pipeline ([`crate::modern::ChunkedAllReduce`]) builds its
/// per-chunk templates with this so every chunk folds through the same
/// chunk-invariant schedule, keeping the chunked result byte-identical
/// to the unchunked one.
pub fn allreduce_init_with(
    comm: &Comm,
    sbuf: Option<&[u8]>,
    rbuf: &mut [u8],
    count: usize,
    dtype: &Datatype,
    op: &Op,
    alg: AllreduceAlg,
) -> Result<PersistentColl> {
    dtype.require_committed()?;
    op.require_reduction()?;
    let sched = builders::allreduce(comm, sbuf, rbuf, count, dtype, op, alg);
    Ok(PersistentColl::new(state(comm, dtype, Some(op.clone()), sched, "allreduce", alg.label())))
}

// ---------------- gather / scatter ----------------

/// `MPI_Gather` (uniform counts).
#[allow(clippy::too_many_arguments)]
pub fn gather(
    comm: &Comm,
    sbuf: &[u8],
    scount: usize,
    sdtype: &Datatype,
    rbuf: Option<&mut [u8]>,
    rcount: usize,
    rdtype: &Datatype,
    root: usize,
) -> Result<()> {
    sdtype.require_committed()?;
    let (counts, displs) = uniform(comm, rcount, rdtype);
    gatherv(comm, sbuf, scount, sdtype, rbuf, &counts, &displs, rdtype, root)
}

/// `MPI_Gatherv` (displacements in **bytes** into the root's recv buffer).
#[allow(clippy::too_many_arguments)]
pub fn gatherv(
    comm: &Comm,
    sbuf: &[u8],
    scount: usize,
    sdtype: &Datatype,
    rbuf: Option<&mut [u8]>,
    rcounts: &[usize],
    rdispls_bytes: &[usize],
    rdtype: &Datatype,
    root: usize,
) -> Result<()> {
    sdtype.require_committed()?;
    let sched =
        builders::gatherv(comm, sbuf, scount, sdtype, rbuf, rcounts, rdispls_bytes, rdtype, root);
    run_blocking(state(comm, sdtype, None, sched, "gatherv", "linear"))
}

/// `MPI_Igatherv`.
#[allow(clippy::too_many_arguments)]
pub fn igatherv(
    comm: &Comm,
    sbuf: &[u8],
    scount: usize,
    sdtype: &Datatype,
    rbuf: Option<&mut [u8]>,
    rcounts: &[usize],
    rdispls_bytes: &[usize],
    rdtype: &Datatype,
    root: usize,
) -> Result<Request> {
    sdtype.require_committed()?;
    let sched =
        builders::gatherv(comm, sbuf, scount, sdtype, rbuf, rcounts, rdispls_bytes, rdtype, root);
    Ok(run_nonblocking(state(comm, sdtype, None, sched, "igatherv", "linear")))
}

/// `MPI_Scatter` (uniform counts).
#[allow(clippy::too_many_arguments)]
pub fn scatter(
    comm: &Comm,
    sbuf: Option<&[u8]>,
    scount: usize,
    sdtype: &Datatype,
    rbuf: &mut [u8],
    rcount: usize,
    rdtype: &Datatype,
    root: usize,
) -> Result<()> {
    rdtype.require_committed()?;
    let (counts, displs) = uniform(comm, scount, sdtype);
    scatterv(comm, sbuf, &counts, &displs, sdtype, rbuf, rcount, rdtype, root)
}

/// `MPI_Scatterv` (displacements in bytes into the root's send buffer).
#[allow(clippy::too_many_arguments)]
pub fn scatterv(
    comm: &Comm,
    sbuf: Option<&[u8]>,
    scounts: &[usize],
    sdispls_bytes: &[usize],
    sdtype: &Datatype,
    rbuf: &mut [u8],
    rcount: usize,
    rdtype: &Datatype,
    root: usize,
) -> Result<()> {
    rdtype.require_committed()?;
    let sched =
        builders::scatterv(comm, sbuf, scounts, sdispls_bytes, sdtype, rbuf, rcount, rdtype, root);
    run_blocking(state(comm, rdtype, None, sched, "scatterv", "linear"))
}

/// `MPI_Iscatterv`.
#[allow(clippy::too_many_arguments)]
pub fn iscatterv(
    comm: &Comm,
    sbuf: Option<&[u8]>,
    scounts: &[usize],
    sdispls_bytes: &[usize],
    sdtype: &Datatype,
    rbuf: &mut [u8],
    rcount: usize,
    rdtype: &Datatype,
    root: usize,
) -> Result<Request> {
    rdtype.require_committed()?;
    let sched =
        builders::scatterv(comm, sbuf, scounts, sdispls_bytes, sdtype, rbuf, rcount, rdtype, root);
    Ok(run_nonblocking(state(comm, rdtype, None, sched, "iscatterv", "linear")))
}

// ---------------- allgather / alltoall ----------------

/// `MPI_Allgather`.
#[allow(clippy::too_many_arguments)]
pub fn allgather(
    comm: &Comm,
    sbuf: Option<&[u8]>,
    scount: usize,
    sdtype: &Datatype,
    rbuf: &mut [u8],
    rcount: usize,
    rdtype: &Datatype,
) -> Result<()> {
    rdtype.require_committed()?;
    let (counts, displs) = uniform(comm, rcount, rdtype);
    allgatherv(comm, sbuf, scount, sdtype, rbuf, &counts, &displs, rdtype)
}

/// `MPI_Allgatherv`.
#[allow(clippy::too_many_arguments)]
pub fn allgatherv(
    comm: &Comm,
    sbuf: Option<&[u8]>,
    scount: usize,
    sdtype: &Datatype,
    rbuf: &mut [u8],
    rcounts: &[usize],
    rdispls_bytes: &[usize],
    rdtype: &Datatype,
) -> Result<()> {
    rdtype.require_committed()?;
    let block = rdtype.size() * rcounts.iter().copied().max().unwrap_or(0);
    let alg = tuned::resolve_allgatherv(comm, block, config::allgatherv_alg());
    let sched =
        builders::allgatherv(comm, sbuf, scount, sdtype, rbuf, rcounts, rdispls_bytes, rdtype, alg);
    run_blocking(state(comm, rdtype, None, sched, "allgatherv", alg.label()))
}

/// `MPI_Iallgatherv`.
#[allow(clippy::too_many_arguments)]
pub fn iallgatherv(
    comm: &Comm,
    sbuf: Option<&[u8]>,
    scount: usize,
    sdtype: &Datatype,
    rbuf: &mut [u8],
    rcounts: &[usize],
    rdispls_bytes: &[usize],
    rdtype: &Datatype,
) -> Result<Request> {
    rdtype.require_committed()?;
    let block = rdtype.size() * rcounts.iter().copied().max().unwrap_or(0);
    let alg = tuned::resolve_allgatherv(comm, block, config::allgatherv_alg());
    let sched =
        builders::allgatherv(comm, sbuf, scount, sdtype, rbuf, rcounts, rdispls_bytes, rdtype, alg);
    Ok(run_nonblocking(state(comm, rdtype, None, sched, "iallgatherv", alg.label())))
}

/// `MPI_Alltoall` (uniform counts).
#[allow(clippy::too_many_arguments)]
pub fn alltoall(
    comm: &Comm,
    sbuf: &[u8],
    scount: usize,
    sdtype: &Datatype,
    rbuf: &mut [u8],
    rcount: usize,
    rdtype: &Datatype,
) -> Result<()> {
    rdtype.require_committed()?;
    let (scounts, sdispls) = uniform(comm, scount, sdtype);
    let (rcounts, rdispls) = uniform(comm, rcount, rdtype);
    alltoallv(comm, sbuf, &scounts, &sdispls, sdtype, rbuf, &rcounts, &rdispls, rdtype)
}

/// `MPI_Alltoallv` (displacements in bytes).
#[allow(clippy::too_many_arguments)]
pub fn alltoallv(
    comm: &Comm,
    sbuf: &[u8],
    scounts: &[usize],
    sdispls_bytes: &[usize],
    sdtype: &Datatype,
    rbuf: &mut [u8],
    rcounts: &[usize],
    rdispls_bytes: &[usize],
    rdtype: &Datatype,
) -> Result<()> {
    rdtype.require_committed()?;
    let block = (scounts.iter().copied().max().unwrap_or(0) * sdtype.size())
        .max(rcounts.iter().copied().max().unwrap_or(0) * rdtype.size());
    let alg = tuned::resolve_alltoallv(comm, block, config::alltoallv_alg());
    let sched = builders::alltoallv(
        comm, sbuf, scounts, sdispls_bytes, sdtype, rbuf, rcounts, rdispls_bytes, rdtype, alg,
    );
    run_blocking(state(comm, rdtype, None, sched, "alltoallv", alg.label()))
}

/// `MPI_Ialltoallv`.
#[allow(clippy::too_many_arguments)]
pub fn ialltoallv(
    comm: &Comm,
    sbuf: &[u8],
    scounts: &[usize],
    sdispls_bytes: &[usize],
    sdtype: &Datatype,
    rbuf: &mut [u8],
    rcounts: &[usize],
    rdispls_bytes: &[usize],
    rdtype: &Datatype,
) -> Result<Request> {
    rdtype.require_committed()?;
    let block = (scounts.iter().copied().max().unwrap_or(0) * sdtype.size())
        .max(rcounts.iter().copied().max().unwrap_or(0) * rdtype.size());
    let alg = tuned::resolve_alltoallv(comm, block, config::alltoallv_alg());
    let sched = builders::alltoallv(
        comm, sbuf, scounts, sdispls_bytes, sdtype, rbuf, rcounts, rdispls_bytes, rdtype, alg,
    );
    Ok(run_nonblocking(state(comm, rdtype, None, sched, "ialltoallv", alg.label())))
}

/// `MPI_Alltoallw` (per-pair datatypes, byte displacements).
#[allow(clippy::too_many_arguments)]
pub fn alltoallw(
    comm: &Comm,
    sbuf: &[u8],
    scounts: &[usize],
    sdispls_bytes: &[usize],
    sdtypes: &[Datatype],
    rbuf: &mut [u8],
    rcounts: &[usize],
    rdispls_bytes: &[usize],
    rdtypes: &[Datatype],
) -> Result<()> {
    for t in sdtypes.iter().chain(rdtypes) {
        t.require_committed()?;
    }
    let sched = builders::alltoallw(
        comm, sbuf, scounts, sdispls_bytes, sdtypes, rbuf, rcounts, rdispls_bytes, rdtypes,
    );
    run_blocking(state(comm, &byte(), None, sched, "alltoallw", "pairwise"))
}

// ---------------- scan / exscan / reduce_scatter ----------------

/// `MPI_Scan` (inclusive prefix).
pub fn scan(
    comm: &Comm,
    sbuf: Option<&[u8]>,
    rbuf: &mut [u8],
    count: usize,
    dtype: &Datatype,
    op: &Op,
) -> Result<()> {
    dtype.require_committed()?;
    op.require_reduction()?;
    let sched = builders::scan(comm, sbuf, rbuf, count, dtype, false);
    run_blocking(state(comm, dtype, Some(op.clone()), sched, "scan", "doubling"))
}

/// `MPI_Exscan` (exclusive prefix; rank 0's output is undefined).
pub fn exscan(
    comm: &Comm,
    sbuf: Option<&[u8]>,
    rbuf: &mut [u8],
    count: usize,
    dtype: &Datatype,
    op: &Op,
) -> Result<()> {
    dtype.require_committed()?;
    op.require_reduction()?;
    let sched = builders::scan(comm, sbuf, rbuf, count, dtype, true);
    run_blocking(state(comm, dtype, Some(op.clone()), sched, "exscan", "doubling"))
}

/// `MPI_Iscan`.
pub fn iscan(
    comm: &Comm,
    sbuf: Option<&[u8]>,
    rbuf: &mut [u8],
    count: usize,
    dtype: &Datatype,
    op: &Op,
) -> Result<Request> {
    dtype.require_committed()?;
    op.require_reduction()?;
    let sched = builders::scan(comm, sbuf, rbuf, count, dtype, false);
    Ok(run_nonblocking(state(comm, dtype, Some(op.clone()), sched, "iscan", "doubling")))
}

/// `MPI_Reduce_scatter` (per-rank result counts).
pub fn reduce_scatter(
    comm: &Comm,
    sbuf: Option<&[u8]>,
    rbuf: &mut [u8],
    rcounts: &[usize],
    dtype: &Datatype,
    op: &Op,
) -> Result<()> {
    dtype.require_committed()?;
    op.require_reduction()?;
    let sched = builders::reduce_scatter(comm, sbuf, rbuf, rcounts, dtype, op)?;
    run_blocking(state(comm, dtype, Some(op.clone()), sched, "reduce_scatter", "reduce+scatterv"))
}

/// `MPI_Reduce_scatter_block` (uniform count per rank).
pub fn reduce_scatter_block(
    comm: &Comm,
    sbuf: Option<&[u8]>,
    rbuf: &mut [u8],
    rcount: usize,
    dtype: &Datatype,
    op: &Op,
) -> Result<()> {
    let counts = vec![rcount; comm.size()];
    reduce_scatter(comm, sbuf, rbuf, &counts, dtype, op)
}
