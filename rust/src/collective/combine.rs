//! The combine engine: how `Step::Reduce` folds one buffer into another.
//!
//! Three implementations sit behind one dispatch point ([`apply`]),
//! selected by the `FERROMPI_COMBINE` knob / `coll_combine_engine` cvar
//! (see [`config::CombineEngine`](super::config::CombineEngine)):
//!
//! * **scalar** — [`Op::apply`]'s per-element `combine_prim` dispatch;
//!   always correct, the ablation baseline.
//! * **native** — the block-wise vectorizable combiner
//!   ([`crate::op::combine_block_native`]) for predefined commutative
//!   ops on contiguous uniform f32/f64/i32/i64 payloads. Arithmetic is
//!   exactly the scalar path's, so results are byte-identical.
//! * **offload** — dispatch BLOCK-sized (4096-element) payloads to the
//!   AOT-lowered Pallas combine kernels through the PJRT engine
//!   ([`crate::runtime`]). f32 sum/prod/max/min only — everything else,
//!   and any engine error, falls back to **native** (counted by the
//!   `combine_fallbacks` pvar). The engine identity-pads the tail block
//!   on the rust side, so non-multiples of 4096 are fine.
//!
//! `auto` (the default) means *native where eligible, scalar otherwise*
//! — offload is opt-in because crossing into PJRT only pays off when a
//! real accelerator backs it.
//!
//! Every eligibility gate here preserves exactness: user ops (whose
//! semantics we cannot see), MINLOC/MAXLOC pair types, logical/bitwise
//! ops, non-uniform typemaps and short buffers all take the scalar path
//! unchanged. The pvars `combine_blocks` / `combine_offloaded` /
//! `combine_fallbacks` on [`FabricStats`] make the dispatch observable.

use super::config::{self, CombineEngine};
use crate::datatype::{Primitive, TypeMap};
use crate::op::{combine_block_native, Op};
use crate::runtime;
use crate::transport::FabricStats;
use crate::Result;
use std::sync::atomic::Ordering;

/// Elements per offload block — re-exported from the runtime so the
/// collective layer has one name for it.
pub use crate::runtime::BLOCK;

/// The primitive shared by every entry of `map`, if the map is uniform
/// and in the block-wise fast set (f32/f64/i32/i64). `None` sends the
/// caller to the scalar path.
fn uniform_prim(map: &TypeMap) -> Option<Primitive> {
    let ents = map.entries();
    let (p0, _) = *ents.first()?;
    if !matches!(p0, Primitive::F32 | Primitive::F64 | Primitive::I32 | Primitive::I64) {
        return None;
    }
    if ents.iter().any(|&(p, _)| p != p0) {
        return None;
    }
    Some(p0)
}

/// Whether `(op, map)` is in the chunkable fast set: a predefined
/// block-wise (hence commutative) op over a contiguous uniform
/// f32/f64/i32/i64 layout. This is the eligibility gate for the chunked
/// reduction pipeline ([`super::tuned::resolve_allreduce_chunking`]):
/// user ops and exotic layouts always take the unchunked, order-exact
/// path.
pub(crate) fn chunk_eligible(op: &Op, map: &TypeMap) -> bool {
    matches!(op, Op::Predefined(k) if k.is_blockwise())
        && map.is_contiguous()
        && uniform_prim(map).is_some()
}

/// Offload one packed f32 payload (`n` values) through the PJRT combine
/// kernels. `inout` is only written on success, so the caller can fall
/// back to the native combiner on error without a partial fold.
fn offload_f32(op: &'static str, input: &[u8], inout: &mut [u8], n: usize) -> Result<()> {
    let mut xs = vec![0f32; n];
    let mut ys = vec![0f32; n];
    for (i, c) in input[..n * 4].chunks_exact(4).enumerate() {
        xs[i] = f32::from_le_bytes(c.try_into().unwrap());
    }
    for (i, c) in inout[..n * 4].chunks_exact(4).enumerate() {
        ys[i] = f32::from_le_bytes(c.try_into().unwrap());
    }
    runtime::engine()?.combine_f32(op, &xs, &mut ys)?;
    for (i, v) in ys.iter().enumerate() {
        inout[i * 4..i * 4 + 4].copy_from_slice(&v.to_le_bytes());
    }
    Ok(())
}

/// `inout[i] = input[i] OP inout[i]` over `count` packed elements of
/// `map`, through the configured combine engine. Semantically identical
/// to [`Op::apply`] for every input; the engines only change *how* the
/// fold is computed, never what it computes.
pub fn apply(
    stats: &FabricStats,
    op: &Op,
    map: &TypeMap,
    input: &[u8],
    inout: &mut [u8],
    count: usize,
) -> Result<()> {
    let sel = config::combine_engine();
    if sel == CombineEngine::Scalar {
        return op.apply(map, input, inout, count);
    }
    // Only predefined block-wise ops on uniform fast-set primitives are
    // eligible; everything else is the scalar path's business.
    let kind = match op {
        Op::Predefined(k) if k.is_blockwise() => *k,
        _ => return op.apply(map, input, inout, count),
    };
    let prim = match uniform_prim(map) {
        Some(p) => p,
        None => return op.apply(map, input, inout, count),
    };
    let need = map.size() * count;
    if input.len() < need || inout.len() < need {
        // Delegate so the error message (and its code) stay the scalar
        // path's.
        return op.apply(map, input, inout, count);
    }
    let n = count * map.entries().len();
    let nblocks = n.div_ceil(BLOCK) as u64;

    if sel == CombineEngine::Offload {
        if prim == Primitive::F32 && runtime::artifacts_available() {
            match offload_f32(kind.name(), input, inout, n) {
                Ok(()) => {
                    stats.combine_blocks.fetch_add(nblocks, Ordering::Relaxed);
                    stats.combine_offloaded.fetch_add(nblocks, Ordering::Relaxed);
                    return Ok(());
                }
                Err(_) => {
                    // Engine refused (client init, compile, execute):
                    // inout is untouched — fold natively instead.
                    stats.combine_fallbacks.fetch_add(1, Ordering::Relaxed);
                }
            }
        } else {
            stats.combine_fallbacks.fetch_add(1, Ordering::Relaxed);
        }
    }

    // Native block-wise combiner (auto, native, and the offload
    // fallback all land here).
    if combine_block_native(kind, prim, input, inout, n) {
        stats.combine_blocks.fetch_add(nblocks, Ordering::Relaxed);
        Ok(())
    } else {
        op.apply(map, input, inout, count)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn le<T: Copy>(v: &[T]) -> Vec<u8> {
        unsafe {
            std::slice::from_raw_parts(v.as_ptr() as *const u8, std::mem::size_of_val(v)).to_vec()
        }
    }

    fn stats() -> FabricStats {
        FabricStats::default()
    }

    #[test]
    fn uniform_prim_gates_correctly() {
        assert_eq!(uniform_prim(&TypeMap::primitive(Primitive::F32)), Some(Primitive::F32));
        assert_eq!(uniform_prim(&TypeMap::primitive(Primitive::I64)), Some(Primitive::I64));
        // Contiguous multiples of a fast primitive stay uniform.
        let c = TypeMap::contiguous(3, &TypeMap::primitive(Primitive::F64));
        assert_eq!(uniform_prim(&c), Some(Primitive::F64));
        // Outside the fast set.
        assert_eq!(uniform_prim(&TypeMap::primitive(Primitive::U16)), None);
        // Mixed pair types (value, i32) are not uniform unless the value
        // is i32 too.
        assert_eq!(uniform_prim(&crate::op::pair_type(Primitive::F32)), None);
    }

    #[test]
    fn chunk_eligibility_gates() {
        let f32m = TypeMap::primitive(Primitive::F32);
        assert!(chunk_eligible(&Op::SUM, &f32m));
        assert!(chunk_eligible(&Op::MIN, &TypeMap::primitive(Primitive::I64)));
        // Logical/bitwise, pair and user ops are never chunked.
        assert!(!chunk_eligible(&Op::BAND, &f32m));
        assert!(!chunk_eligible(&Op::MAXLOC, &crate::op::pair_type(Primitive::F32)));
        let f: crate::op::UserFn = std::sync::Arc::new(|_, _, _, _| Ok(()));
        assert!(!chunk_eligible(&Op::user(f, true, "u"), &f32m));
        // Non-fast primitives and non-contiguous layouts stay unchunked.
        assert!(!chunk_eligible(&Op::SUM, &TypeMap::primitive(Primitive::U16)));
        let strided = TypeMap::vector(2, 1, 4, &TypeMap::primitive(Primitive::F32));
        assert!(!chunk_eligible(&Op::SUM, &strided));
    }

    #[test]
    fn engines_match_scalar_bytes() {
        let s = stats();
        let map = TypeMap::primitive(Primitive::F32);
        let n = 1000;
        let xs: Vec<f32> = (0..n).map(|i| i as f32 * 0.25 - 100.0).collect();
        let ys: Vec<f32> = (0..n).map(|i| (n - i) as f32 * 0.5).collect();
        for op in [Op::SUM, Op::PROD, Op::MAX, Op::MIN] {
            let mut scalar = le(&ys);
            op.apply(&map, &le(&xs), &mut scalar, n).unwrap();
            let mut fast = le(&ys);
            apply(&s, &op, &map, &le(&xs), &mut fast, n).unwrap();
            assert_eq!(scalar, fast, "{op:?} diverged from the scalar fold");
        }
        assert!(s.combine_blocks.load(Ordering::Relaxed) > 0);
    }

    #[test]
    fn ineligible_shapes_fall_back_to_scalar() {
        let s = stats();
        // Logical op: not block-wise, must still be correct.
        let map = TypeMap::primitive(Primitive::I32);
        let xs = le(&[1i32, 0, 5]);
        let mut ys = le(&[1i32, 1, 0]);
        apply(&s, &Op::LAND, &map, &xs, &mut ys, 3).unwrap();
        let got: Vec<i32> =
            ys.chunks(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect();
        assert_eq!(got, vec![1, 0, 0]);
        assert_eq!(s.combine_blocks.load(Ordering::Relaxed), 0);
        // Replace is rejected with the scalar path's reduction error
        // class untouched — it never reaches a block engine.
        assert!(Op::REPLACE.require_reduction().is_err());
    }

    #[test]
    fn short_buffers_error_like_scalar() {
        let s = stats();
        let map = TypeMap::primitive(Primitive::F64);
        let xs = le(&[1f64]);
        let mut ys = le(&[2f64]);
        let e = apply(&s, &Op::SUM, &map, &xs, &mut ys, 2).unwrap_err();
        let e2 = Op::SUM.apply(&map, &xs, &mut ys, 2).unwrap_err();
        assert_eq!(e.class, e2.class);
    }

    #[test]
    fn block_counting_rounds_up() {
        let s = stats();
        let map = TypeMap::primitive(Primitive::I64);
        let n = BLOCK + 1; // two blocks' worth
        let xs: Vec<i64> = (0..n as i64).collect();
        let mut ys = le(&vec![1i64; n]);
        apply(&s, &Op::SUM, &map, &le(&xs), &mut ys, n).unwrap();
        assert_eq!(s.combine_blocks.load(Ordering::Relaxed), 2);
        let got0 = i64::from_le_bytes(ys[0..8].try_into().unwrap());
        assert_eq!(got0, 1);
    }

    #[test]
    fn offload_without_artifacts_counts_a_fallback() {
        if runtime::artifacts_available() {
            return; // this test is about the artifact-less path
        }
        let s = stats();
        let map = TypeMap::primitive(Primitive::F32);
        let xs = le(&[1f32, 2.0]);
        let mut ys = le(&[10f32, 20.0]);
        // Serializes with every other test that writes the combine knobs.
        let g = crate::sim::chaos::CVAR_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        config::set_combine_engine(CombineEngine::Offload);
        let r = apply(&s, &Op::SUM, &map, &xs, &mut ys, 2);
        config::set_combine_engine(CombineEngine::Auto);
        drop(g);
        r.unwrap();
        assert_eq!(s.combine_fallbacks.load(Ordering::Relaxed), 1);
        assert_eq!(s.combine_offloaded.load(Ordering::Relaxed), 0);
        let got: Vec<f32> =
            ys.chunks(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect();
        assert_eq!(got, vec![11.0, 22.0]);
    }
}
