//! Collective algorithm selection knobs.
//!
//! One process-global knob per tunable collective, each surfaced three
//! ways with a fixed precedence (first hit wins):
//!
//! 1. an `MPI_T` **cvar write** (`coll_*_algorithm`, see
//!    [`crate::tool::cvar`]) — or the equivalent programmatic `set_*`,
//! 2. a `FERROMPI_COLL_*` **environment override** (read once, cached),
//! 3. the built-in default, [`Auto`](BcastAlg::Auto).
//!
//! `Auto` is not an algorithm: it is resolved to a concrete variant at
//! schedule-build time by the decision tables in
//! [`tuned`](super::tuned), keyed on message size, communicator size,
//! node topology and the eager threshold. Persistent collectives resolve
//! `Auto` exactly once, at init — the template then replays the captured
//! algorithm no matter how the knobs move afterwards.

use crate::{mpi_err, Result};
use std::sync::atomic::{AtomicU8, Ordering};

/// Broadcast algorithm (cvar `coll_bcast_algorithm`, env
/// `FERROMPI_COLL_BCAST`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BcastAlg {
    /// Pick per call from the decision table.
    Auto,
    /// Binomial tree: `ceil(log2 p)` rounds, latency-optimal.
    Binomial,
    /// Root sends to everyone (the ablation baseline; `O(p)` at the root).
    Linear,
    /// Node-aware: binomial over node leaders, then intra-node fan-out.
    Hier,
}

/// Allreduce algorithm (cvar `coll_allreduce_algorithm`, env
/// `FERROMPI_COLL_ALLREDUCE`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AllreduceAlg {
    /// Pick per call from the decision table.
    Auto,
    /// Recursive doubling: `ceil(log2 p)` full-vector exchanges.
    RecursiveDoubling,
    /// Reduce-scatter + allgather rings: bandwidth-optimal for large
    /// vectors.
    Ring,
    /// Ordered reduce to rank 0 + broadcast: the only order-exact choice
    /// for non-commutative ops (forced for those regardless of the knob).
    ReduceBcast,
    /// Node-aware: intra-node fold to leaders, recursive doubling across
    /// leaders, intra-node fan-out.
    Hier,
}

/// Reduce algorithm (cvar `coll_reduce_algorithm`, env
/// `FERROMPI_COLL_REDUCE`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReduceAlg {
    /// Pick per call from the decision table.
    Auto,
    /// Binomial reduction tree toward the root.
    Binomial,
    /// Ordered linear gather-fold at the root (forced for non-commutative
    /// ops regardless of the knob).
    Linear,
    /// Node-aware: intra-node fold to leaders, binomial across leaders.
    Hier,
}

/// Allgather(v) algorithm (cvar `coll_allgatherv_algorithm`, env
/// `FERROMPI_COLL_ALLGATHERV`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AllgathervAlg {
    /// Pick per call from the decision table.
    Auto,
    /// Neighbor ring, `p-1` pipelined rounds: bounded in-flight data.
    Ring,
    /// Every pair exchanges directly in a single round: one latency for
    /// small blocks.
    Spread,
}

/// Alltoall(v) algorithm (cvar `coll_alltoallv_algorithm`, env
/// `FERROMPI_COLL_ALLTOALLV`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AlltoallvAlg {
    /// Pick per call from the decision table.
    Auto,
    /// Rotation schedule: one send+recv per round, `p-1` rounds.
    Pairwise,
    /// Post every send and receive in a single round.
    Spread,
}

/// Combine engine for predefined reductions (cvar `coll_combine_engine`,
/// env `FERROMPI_COMBINE`): how `Step::Reduce` combines payloads — see
/// [`combine`](super::combine).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CombineEngine {
    /// Native block-wise combine where eligible, scalar otherwise.
    Auto,
    /// The original per-element `Op::apply` loop (the ablation baseline).
    Scalar,
    /// Block-wise vectorizable Rust loops for the arithmetic ops on
    /// contiguous f32/f64/i32/i64.
    Native,
    /// AOT-Pallas-via-PJRT combine for f32 arithmetic ops (falls back to
    /// `Native` when the artifacts are absent).
    Offload,
}

const UNSET: u8 = u8::MAX;
const NO_ENV: u8 = u8::MAX - 1;

/// Storage for one knob: the cvar cell (written value wins), plus a
/// lazily resolved, cached env override. Values are indices into the
/// enum's `VALUES` table; `UNSET`/`NO_ENV` are sentinels.
struct Knob {
    cell: AtomicU8,
    env_cell: AtomicU8,
    env: &'static str,
}

impl Knob {
    const fn new(env: &'static str) -> Knob {
        Knob { cell: AtomicU8::new(UNSET), env_cell: AtomicU8::new(UNSET), env }
    }

    fn get<T: Copy>(&self, values: &'static [(&'static str, T)], default: T) -> T {
        let v = self.cell.load(Ordering::Relaxed);
        if (v as usize) < values.len() {
            return values[v as usize].1;
        }
        let mut e = self.env_cell.load(Ordering::Relaxed);
        if e == UNSET {
            e = match std::env::var(self.env) {
                Ok(s) => resolve_env_index(values, &s),
                Err(_) => NO_ENV,
            };
            self.env_cell.store(e, Ordering::Relaxed);
        }
        if (e as usize) < values.len() {
            values[e as usize].1
        } else {
            default
        }
    }

    fn set<T: Copy + PartialEq>(&self, values: &'static [(&'static str, T)], v: T) {
        let idx = values.iter().position(|(_, x)| *x == v).expect("variant in VALUES table");
        self.cell.store(idx as u8, Ordering::Relaxed);
    }
}

/// Pure env-value resolver (unit-testable without touching the process
/// environment): the trimmed value must match a table spelling exactly;
/// anything else falls through to the default.
fn resolve_env_index<T>(values: &[(&'static str, T)], s: &str) -> u8 {
    let t = s.trim();
    values.iter().position(|(n, _)| *n == t).map(|i| i as u8).unwrap_or(NO_ENV)
}

/// Shared parser: exact spelling from the `VALUES` table, or an `Arg`
/// error that lists every valid value (the cvar writer sees this).
fn parse_from<T: Copy>(
    values: &'static [(&'static str, T)],
    what: &str,
    s: &str,
) -> Result<T> {
    values.iter().find(|(n, _)| *n == s).map(|(_, v)| *v).ok_or_else(|| {
        let valid: Vec<&str> = values.iter().map(|(n, _)| *n).collect();
        mpi_err!(Arg, "unknown {what} algorithm '{s}' (valid: {})", valid.join(" | "))
    })
}

macro_rules! knob {
    ($enum:ident, $what:literal, $static:ident, $get:ident, $set:ident, $parse:ident,
     $env:literal, [ $(($name:literal, $var:ident)),+ $(,)? ]) => {
        impl $enum {
            /// cvar/env spelling ↔ variant table.
            pub const VALUES: &'static [(&'static str, $enum)] = &[ $( ($name, $enum::$var) ),+ ];

            /// The cvar/env spelling of this variant.
            pub fn label(self) -> &'static str {
                Self::VALUES.iter().find(|(_, v)| *v == self).map(|(n, _)| *n).unwrap()
            }
        }

        static $static: Knob = Knob::new($env);

        #[doc = concat!(
            "Current knob value: a written cvar wins, then the `",
            $env,
            "` environment override, then `Auto`."
        )]
        pub fn $get() -> $enum {
            $static.get($enum::VALUES, $enum::Auto)
        }

        /// Programmatic knob write (what a cvar write lands on).
        pub fn $set(a: $enum) {
            $static.set($enum::VALUES, a);
        }

        /// Parse a cvar value; the error lists the valid spellings.
        pub fn $parse(s: &str) -> Result<$enum> {
            parse_from($enum::VALUES, $what, s)
        }
    };
}

knob!(BcastAlg, "bcast", BCAST, bcast_alg, set_bcast_alg, parse_bcast_alg,
    "FERROMPI_COLL_BCAST",
    [("auto", Auto), ("binomial", Binomial), ("linear", Linear), ("hier", Hier)]);

knob!(AllreduceAlg, "allreduce", ALLREDUCE, allreduce_alg, set_allreduce_alg, parse_allreduce_alg,
    "FERROMPI_COLL_ALLREDUCE",
    [("auto", Auto), ("recursive_doubling", RecursiveDoubling), ("ring", Ring),
     ("reduce_bcast", ReduceBcast), ("hier", Hier)]);

knob!(ReduceAlg, "reduce", REDUCE, reduce_alg, set_reduce_alg, parse_reduce_alg,
    "FERROMPI_COLL_REDUCE",
    [("auto", Auto), ("binomial", Binomial), ("linear", Linear), ("hier", Hier)]);

knob!(AllgathervAlg, "allgatherv", ALLGATHERV, allgatherv_alg, set_allgatherv_alg, parse_allgatherv_alg,
    "FERROMPI_COLL_ALLGATHERV",
    [("auto", Auto), ("ring", Ring), ("spread", Spread)]);

knob!(AlltoallvAlg, "alltoallv", ALLTOALLV, alltoallv_alg, set_alltoallv_alg, parse_alltoallv_alg,
    "FERROMPI_COLL_ALLTOALLV",
    [("auto", Auto), ("pairwise", Pairwise), ("spread", Spread)]);

knob!(CombineEngine, "combine", COMBINE, combine_engine, set_combine_engine, parse_combine_engine,
    "FERROMPI_COMBINE",
    [("auto", Auto), ("scalar", Scalar), ("native", Native), ("offload", Offload)]);

// ---------------- chunking threshold ----------------

use std::sync::atomic::AtomicU64;
use std::sync::OnceLock;

/// Default chunked-reduction threshold in bytes: payloads at or above it
/// are split into combine-block-aligned chunks whose schedules run
/// concurrently (combine of chunk *i* overlaps transfers of chunk *i+1*).
pub const DEFAULT_CHUNK_THRESHOLD: usize = 128 * 1024;

/// Cvar override (`coll_chunk_threshold`); 0 = unset (defer to env).
static CHUNK_OVERRIDE: AtomicU64 = AtomicU64::new(0);
/// `FERROMPI_COMBINE_CHUNK`, read once per process like every other knob.
static CHUNK_ENV: OnceLock<Option<String>> = OnceLock::new();

/// Positive-integer env/cvar value; zero and malformed spellings fall
/// through to the next precedence level.
fn parse_positive(s: &str) -> Option<usize> {
    s.trim().parse::<usize>().ok().filter(|&v| v > 0)
}

/// Pure precedence resolver (unit-testable without touching the process
/// environment): a written cvar wins, then a positive env override, then
/// the default.
fn resolve_chunk_threshold(cvar: u64, env: Option<&str>, default: usize) -> usize {
    if cvar > 0 {
        return cvar as usize;
    }
    env.and_then(parse_positive).unwrap_or(default)
}

/// Effective chunking threshold in bytes (cvar `coll_chunk_threshold` >
/// env `FERROMPI_COMBINE_CHUNK` > [`DEFAULT_CHUNK_THRESHOLD`]).
pub fn chunk_threshold() -> usize {
    let env = CHUNK_ENV.get_or_init(|| std::env::var("FERROMPI_COMBINE_CHUNK").ok());
    resolve_chunk_threshold(
        CHUNK_OVERRIDE.load(Ordering::Relaxed),
        env.as_deref(),
        DEFAULT_CHUNK_THRESHOLD,
    )
}

/// Programmatic threshold write (what a `coll_chunk_threshold` cvar write
/// lands on); 0 restores the env/default precedence.
pub fn set_chunk_threshold(bytes: u64) {
    CHUNK_OVERRIDE.store(bytes, Ordering::Relaxed);
}

#[cfg(test)]
mod tests {
    use super::*;

    // The setters are macro-generated identically for every knob, so one
    // knob covers them; sticking to allreduce avoids racing the cvar-layer
    // roundtrip test (same process, other knobs) under the parallel test
    // runner.
    #[test]
    fn roundtrip_settings() {
        set_allreduce_alg(AllreduceAlg::Ring);
        assert_eq!(allreduce_alg(), AllreduceAlg::Ring);
        set_allreduce_alg(AllreduceAlg::Hier);
        assert_eq!(allreduce_alg(), AllreduceAlg::Hier);
        set_allreduce_alg(AllreduceAlg::Auto);
        assert_eq!(allreduce_alg(), AllreduceAlg::Auto);
    }

    #[test]
    fn parsing_accepts_every_spelling() {
        assert_eq!(parse_bcast_alg("linear").unwrap(), BcastAlg::Linear);
        assert_eq!(parse_bcast_alg("hier").unwrap(), BcastAlg::Hier);
        assert_eq!(parse_allreduce_alg("ring").unwrap(), AllreduceAlg::Ring);
        assert_eq!(parse_allreduce_alg("auto").unwrap(), AllreduceAlg::Auto);
        assert_eq!(parse_reduce_alg("binomial").unwrap(), ReduceAlg::Binomial);
        assert_eq!(parse_allgatherv_alg("spread").unwrap(), AllgathervAlg::Spread);
        assert_eq!(parse_alltoallv_alg("pairwise").unwrap(), AlltoallvAlg::Pairwise);
    }

    #[test]
    fn parse_error_lists_valid_values() {
        let err = parse_allreduce_alg("nope").unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("nope"), "{msg}");
        for valid in ["auto", "recursive_doubling", "ring", "reduce_bcast", "hier"] {
            assert!(msg.contains(valid), "missing '{valid}' in: {msg}");
        }
        assert!(parse_bcast_alg("Binomial").is_err(), "spellings are case-sensitive");
    }

    #[test]
    fn labels_roundtrip_through_parse() {
        for (name, v) in BcastAlg::VALUES {
            assert_eq!(v.label(), *name);
            assert_eq!(parse_bcast_alg(name).unwrap(), *v);
        }
        for (name, v) in AllreduceAlg::VALUES {
            assert_eq!(parse_allreduce_alg(name).unwrap(), *v);
        }
    }

    #[test]
    fn combine_engine_knob_roundtrips() {
        assert_eq!(parse_combine_engine("scalar").unwrap(), CombineEngine::Scalar);
        assert_eq!(parse_combine_engine("native").unwrap(), CombineEngine::Native);
        assert_eq!(parse_combine_engine("offload").unwrap(), CombineEngine::Offload);
        let msg = format!("{}", parse_combine_engine("gpu").unwrap_err());
        for valid in ["auto", "scalar", "native", "offload"] {
            assert!(msg.contains(valid), "missing '{valid}' in: {msg}");
        }
        for (name, v) in CombineEngine::VALUES {
            assert_eq!(v.label(), *name);
        }
        // The set/get round-trip mutates the process-global knob:
        // serialize against the other combine-knob tests.
        let _g = crate::sim::chaos::CVAR_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        set_combine_engine(CombineEngine::Native);
        assert_eq!(combine_engine(), CombineEngine::Native);
        set_combine_engine(CombineEngine::Auto);
        assert_eq!(combine_engine(), CombineEngine::Auto);
    }

    #[test]
    fn chunk_threshold_precedence() {
        // cvar > env > default; malformed / zero values fall through.
        assert_eq!(resolve_chunk_threshold(4096, Some("8192"), 131072), 4096);
        assert_eq!(resolve_chunk_threshold(0, Some("8192"), 131072), 8192);
        assert_eq!(resolve_chunk_threshold(0, Some(" 512 "), 131072), 512);
        assert_eq!(resolve_chunk_threshold(0, Some("0"), 131072), 131072);
        assert_eq!(resolve_chunk_threshold(0, Some("wat"), 131072), 131072);
        assert_eq!(resolve_chunk_threshold(0, None, 131072), 131072);
    }

    #[test]
    fn env_resolver_is_exact_and_trimmed() {
        assert_eq!(resolve_env_index(BcastAlg::VALUES, "hier"), 3);
        assert_eq!(resolve_env_index(BcastAlg::VALUES, " binomial "), 1);
        assert_eq!(resolve_env_index(BcastAlg::VALUES, "HIER"), NO_ENV);
        assert_eq!(resolve_env_index(BcastAlg::VALUES, ""), NO_ENV);
        assert_eq!(resolve_env_index(BcastAlg::VALUES, "wat"), NO_ENV);
    }
}
