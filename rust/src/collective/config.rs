//! Collective algorithm selection knobs. These are process-global control
//! variables, surfaced through the tool (`MPI_T`) interface as cvars and
//! swept by the A4 ablation benchmark.

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BcastAlg {
    Binomial = 0,
    Linear = 1,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AllreduceAlg {
    RecursiveDoubling = 0,
    Ring = 1,
    ReduceBcast = 2,
}

static BCAST_ALG: AtomicU8 = AtomicU8::new(0);
static ALLREDUCE_ALG: AtomicU8 = AtomicU8::new(0);

pub fn bcast_alg() -> BcastAlg {
    match BCAST_ALG.load(Ordering::Relaxed) {
        1 => BcastAlg::Linear,
        _ => BcastAlg::Binomial,
    }
}

pub fn set_bcast_alg(a: BcastAlg) {
    BCAST_ALG.store(a as u8, Ordering::Relaxed);
}

pub fn allreduce_alg() -> AllreduceAlg {
    match ALLREDUCE_ALG.load(Ordering::Relaxed) {
        1 => AllreduceAlg::Ring,
        2 => AllreduceAlg::ReduceBcast,
        _ => AllreduceAlg::RecursiveDoubling,
    }
}

pub fn set_allreduce_alg(a: AllreduceAlg) {
    ALLREDUCE_ALG.store(a as u8, Ordering::Relaxed);
}

/// Parse from a cvar string value.
pub fn parse_bcast_alg(s: &str) -> Option<BcastAlg> {
    match s {
        "binomial" => Some(BcastAlg::Binomial),
        "linear" => Some(BcastAlg::Linear),
        _ => None,
    }
}

pub fn parse_allreduce_alg(s: &str) -> Option<AllreduceAlg> {
    match s {
        "recursive_doubling" => Some(AllreduceAlg::RecursiveDoubling),
        "ring" => Some(AllreduceAlg::Ring),
        "reduce_bcast" => Some(AllreduceAlg::ReduceBcast),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_settings() {
        set_bcast_alg(BcastAlg::Linear);
        assert_eq!(bcast_alg(), BcastAlg::Linear);
        set_bcast_alg(BcastAlg::Binomial);
        assert_eq!(bcast_alg(), BcastAlg::Binomial);
        set_allreduce_alg(AllreduceAlg::Ring);
        assert_eq!(allreduce_alg(), AllreduceAlg::Ring);
        set_allreduce_alg(AllreduceAlg::RecursiveDoubling);
    }

    #[test]
    fn parsing() {
        assert_eq!(parse_bcast_alg("linear"), Some(BcastAlg::Linear));
        assert_eq!(parse_allreduce_alg("ring"), Some(AllreduceAlg::Ring));
        assert_eq!(parse_allreduce_alg("nope"), None);
    }
}
