//! The round-based collective execution engine (libNBC-style).
//!
//! Every collective — blocking or nonblocking — is expressed as a
//! [`Schedule`]: a sequence of *rounds*, each a set of steps (sends,
//! receives, local copies/reductions, user-buffer pack/unpack). A round
//! only starts when every transfer of the previous round has completed.
//! Blocking collectives drive the schedule to completion inside the call;
//! nonblocking ones wrap it in a request and the progress engine turns it.
//!
//! Wire data lives in a per-operation *arena* (allocated once, never
//! reallocated, so raw-pointer ranges into it stay valid). The arena is
//! checked out of the fabric's wire-buffer pool and recycled when the
//! operation drops, so steady-state collective traffic allocates nothing.
//! All arena data is in packed wire format; `PackUser`/`UnpackUser`
//! convert at the edges.

use crate::datatype::{pack_into, unpack, Datatype};
use crate::group::Group;
use crate::op::Op;
use crate::p2p::{self, engine, Progressable, RankCtx, RawBuf, RawBufMut, SendMode, Status};
use crate::request::CustomRequest;
use crate::{mpi_err, MpiError, Result};
use std::cell::{Cell, RefCell};
use std::rc::Rc;

/// A byte range in the operation's arena.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ArenaRange {
    pub off: usize,
    pub len: usize,
}

/// One step of a round. Peers are *world* ranks (translated at build
/// time). `tag_off` disambiguates multiple same-peer transfers in a round
/// (both sides must assign matching offsets).
#[derive(Debug)]
pub enum Step {
    Send { peer_world: usize, from: ArenaRange, tag_off: u8 },
    Recv { peer_world: usize, into: ArenaRange, tag_off: u8 },
    Copy { from: ArenaRange, to: ArenaRange },
    /// `into = from OP into` over `count` packed elements.
    Reduce { from: ArenaRange, into: ArenaRange, count: usize },
    PackUser { src: RawBuf, count: usize, dtype: Datatype, to: ArenaRange },
    UnpackUser { from: ArenaRange, dst: RawBufMut, count: usize, dtype: Datatype },
}

/// A built schedule plus its arena requirement.
#[derive(Debug, Default)]
pub struct Schedule {
    pub rounds: Vec<Vec<Step>>,
    pub arena_size: usize,
}

/// Builder helper used by the per-collective algorithms.
#[derive(Debug, Default)]
pub struct SchedBuilder {
    rounds: Vec<Vec<Step>>,
    arena_size: usize,
    current: Vec<Step>,
}

impl SchedBuilder {
    pub fn new() -> SchedBuilder {
        SchedBuilder::default()
    }

    /// Reserve `len` arena bytes.
    pub fn alloc(&mut self, len: usize) -> ArenaRange {
        let off = self.arena_size;
        self.arena_size += len;
        ArenaRange { off, len }
    }

    /// Close the current round (no-op if empty).
    pub fn barrier_round(&mut self) {
        if !self.current.is_empty() {
            self.rounds.push(std::mem::take(&mut self.current));
        }
    }

    pub fn step(&mut self, s: Step) {
        self.current.push(s);
    }

    pub fn send(&mut self, peer_world: usize, from: ArenaRange) {
        self.step(Step::Send { peer_world, from, tag_off: 0 });
    }

    pub fn send_tagged(&mut self, peer_world: usize, from: ArenaRange, tag_off: u8) {
        self.step(Step::Send { peer_world, from, tag_off });
    }

    pub fn recv(&mut self, peer_world: usize, into: ArenaRange) {
        self.step(Step::Recv { peer_world, into, tag_off: 0 });
    }

    pub fn recv_tagged(&mut self, peer_world: usize, into: ArenaRange, tag_off: u8) {
        self.step(Step::Recv { peer_world, into, tag_off });
    }

    pub fn copy(&mut self, from: ArenaRange, to: ArenaRange) {
        self.step(Step::Copy { from, to });
    }

    pub fn reduce(&mut self, from: ArenaRange, into: ArenaRange, count: usize) {
        self.step(Step::Reduce { from, into, count });
    }

    pub fn pack_user(&mut self, src: &[u8], count: usize, dtype: &Datatype, to: ArenaRange) {
        self.step(Step::PackUser { src: RawBuf::from_slice(src), count, dtype: dtype.clone(), to });
    }

    pub fn unpack_user(&mut self, from: ArenaRange, dst: &mut [u8], count: usize, dtype: &Datatype) {
        self.step(Step::UnpackUser { from, dst: RawBufMut::from_slice(dst), count, dtype: dtype.clone() });
    }

    /// Capture-based variants for disjoint sub-buffers the borrow checker
    /// cannot see through (gatherv/scatterv displacements).
    pub fn pack_user_raw(&mut self, src: RawBuf, count: usize, dtype: &Datatype, to: ArenaRange) {
        self.step(Step::PackUser { src, count, dtype: dtype.clone(), to });
    }

    pub fn unpack_user_raw(&mut self, from: ArenaRange, dst: RawBufMut, count: usize, dtype: &Datatype) {
        self.step(Step::UnpackUser { from, dst, count, dtype: dtype.clone() });
    }

    pub fn finish(mut self) -> Schedule {
        self.barrier_round();
        Schedule { rounds: self.rounds, arena_size: self.arena_size }
    }
}

/// Executing state of one collective operation. Implements both
/// [`Progressable`] (so the engine turns it) and [`CustomRequest`] (so a
/// nonblocking collective is an ordinary request).
pub struct CollState {
    ctx: Rc<RankCtx>,
    ctx_coll: u32,
    base_tag: i32,
    group: Group,
    dtype: Datatype,
    op: Option<Op>,
    schedule: Schedule,
    arena: RefCell<Vec<u8>>,
    round: Cell<usize>,
    outstanding_sends: RefCell<Vec<u64>>,
    outstanding_recvs: RefCell<Vec<u64>>,
    done: Cell<bool>,
    error: RefCell<Option<MpiError>>,
    /// Whether this state is currently registered with the progress
    /// engine (kept accurate by [`CollState::register_in_engine`] and the
    /// engine-driven `advance`, so a persistent restart never
    /// double-registers).
    in_engine: Cell<bool>,
    /// Set when a reset found a receive it could not cancel (already
    /// matched an RTS: RData inbound targeting raw pointers into the
    /// arena). A tainted arena is never reused: a restart (`reset`)
    /// swaps in a fresh one and `Drop` leaks rather than recycles it.
    tainted: Cell<bool>,
    /// Label for diagnostics ("bcast", "allreduce", ...).
    pub name: &'static str,
    /// The concrete algorithm this schedule was built with ("binomial",
    /// "ring", "hier", ...): `Auto` knobs are resolved *before* the
    /// schedule exists, so this is fixed for the state's lifetime — the
    /// capture persistent collectives replay across restarts.
    pub alg: &'static str,
}

/// How many distinct tag offsets a round may use.
const TAG_SPACE: i64 = 64;

impl CollState {
    pub fn new(
        ctx: Rc<RankCtx>,
        ctx_coll: u32,
        group: Group,
        dtype: Datatype,
        op: Option<Op>,
        schedule: Schedule,
        name: &'static str,
        alg: &'static str,
    ) -> Rc<CollState> {
        let seq = ctx.next_coll_seq(ctx_coll);
        ctx.counters.collectives_started.set(ctx.counters.collectives_started.get() + 1);
        let base_tag = ((seq as i64 * TAG_SPACE) % (crate::comm::TAG_UB as i64)) as i32;
        let mut arena = ctx.fabric.pool.take_vec(schedule.arena_size);
        arena.resize(schedule.arena_size, 0);
        Rc::new(CollState {
            ctx,
            ctx_coll,
            base_tag,
            group,
            dtype,
            op,
            schedule,
            arena: RefCell::new(arena),
            round: Cell::new(0),
            outstanding_sends: RefCell::new(Vec::new()),
            outstanding_recvs: RefCell::new(Vec::new()),
            done: Cell::new(false),
            error: RefCell::new(None),
            in_engine: Cell::new(false),
            tainted: Cell::new(false),
            name,
            alg,
        })
    }

    pub(crate) fn rank_ctx(&self) -> &Rc<RankCtx> {
        &self.ctx
    }

    /// Drain outstanding transfers (error-path cleanup shared by `reset`
    /// and `Drop`): cancellable receives are cancelled and consumed, send
    /// tokens drained best-effort. Returns `false` if a receive had
    /// already matched an RTS and could not be cancelled — its RData is
    /// inbound, addressed to raw pointers into this arena.
    fn drain_outstanding(&self) -> bool {
        let mut clean = true;
        for t in self.outstanding_recvs.borrow_mut().drain(..) {
            match engine::cancel_recv(&self.ctx, t) {
                Ok(true) => {
                    let _ = engine::take_recv_result(&self.ctx, t);
                }
                _ => clean = false,
            }
        }
        for t in self.outstanding_sends.borrow_mut().drain(..) {
            let _ = engine::take_send_done(&self.ctx, t);
        }
        clean
    }

    /// Rewind a completed schedule so it can run again (the persistent
    /// collective restart, MPI-4.0 §6.13). On the happy path the arena is
    /// kept — same allocation, re-zeroed — and the schedule, datatype
    /// handle and tag base are untouched, so a restart allocates nothing.
    ///
    /// Caller contract: only when the previous run finished (successfully
    /// or with an error) or the state was never started. A successful run
    /// leaves no outstanding transfers; a run that *errored* mid-schedule
    /// may — its still-posted receives are cancelled here (they share the
    /// restart's tags and would otherwise steal its messages), its send
    /// tokens drained best-effort. A receive that cannot be cancelled has
    /// rendezvous data inbound into the arena, so that arena is retired
    /// (leaked) and the restart gets a fresh one — never a corruptible or
    /// recycled buffer.
    pub(crate) fn reset(&self) {
        if !self.drain_outstanding() || self.tainted.get() {
            // A receive already matched an RTS: its RData is inbound,
            // addressed to raw pointers into the *current* arena. Retire
            // that allocation (leaked, never recycled — the late delivery
            // lands in dead-but-still-allocated memory) and run the
            // restart in a fresh arena so it cannot be corrupted.
            let mut fresh = self.ctx.fabric.pool.take_vec(self.schedule.arena_size);
            fresh.resize(self.schedule.arena_size, 0);
            let old = std::mem::replace(&mut *self.arena.borrow_mut(), fresh);
            std::mem::forget(old);
            self.tainted.set(false);
        }
        self.round.set(0);
        self.done.set(false);
        *self.error.borrow_mut() = None;
        self.arena.borrow_mut().fill(0);
    }

    /// Register with the progress engine unless already registered.
    pub(crate) fn register_in_engine(self: &Rc<Self>) {
        if !self.in_engine.get() {
            self.in_engine.set(true);
            self.ctx.register_progressable(self.clone());
        }
    }

    fn tag(&self, off: u8) -> i32 {
        self.base_tag + off as i32
    }

    pub fn finished(&self) -> bool {
        self.done.get() || self.error.borrow().is_some()
    }

    pub fn take_result(&self) -> Result<()> {
        match self.error.borrow_mut().take() {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// Execute one step. Transfers are posted (tokens recorded); local
    /// steps run immediately.
    fn exec_step(&self, step: &Step) -> Result<()> {
        let byte = Datatype::primitive(crate::datatype::Primitive::Byte);
        match step {
            Step::Send { peer_world, from, tag_off } => {
                let arena = self.arena.borrow();
                let data = &arena[from.off..from.off + from.len];
                let token = engine::start_send(
                    &self.ctx,
                    p2p::SendParams {
                        ctx_id: self.ctx_coll,
                        dst_world: *peer_world,
                        tag: self.tag(*tag_off),
                        buf: data,
                        count: from.len,
                        dtype: &byte,
                        mode: SendMode::Standard,
                        // Later rounds may rewrite this arena range before
                        // a rendezvous CTS arrives, so the payload must be
                        // staged (into a pooled buffer) at post time.
                        staging: p2p::RndvStaging::Staged,
                    },
                )?;
                drop(arena);
                if let Some(t) = token {
                    self.outstanding_sends.borrow_mut().push(t);
                }
            }
            Step::Recv { peer_world, into, tag_off } => {
                // Raw pointer into the fixed-size arena; delivery happens on
                // this same thread with no arena borrow held.
                let buf = {
                    let mut arena = self.arena.borrow_mut();
                    let slice = &mut arena[into.off..into.off + into.len];
                    RawBufMut::from_slice(slice)
                };
                let token = engine::post_recv(
                    &self.ctx,
                    self.ctx_coll,
                    Some(*peer_world),
                    Some(self.tag(*tag_off)),
                    buf,
                    into.len,
                    byte,
                    self.group.clone(),
                )?;
                self.outstanding_recvs.borrow_mut().push(token);
            }
            Step::Copy { from, to } => {
                if from.len != to.len {
                    return Err(mpi_err!(Intern, "schedule copy length mismatch"));
                }
                let mut arena = self.arena.borrow_mut();
                arena.copy_within(from.off..from.off + from.len, to.off);
                self.ctx.fabric.pool.count_copied(from.len);
            }
            Step::Reduce { from, into, count } => {
                let op = self
                    .op
                    .as_ref()
                    .ok_or_else(|| mpi_err!(Intern, "reduce step without an op"))?;
                let mut arena = self.arena.borrow_mut();
                // Split-borrow the two ranges.
                let (a, b) = split_ranges(&mut arena, *from, *into)?;
                super::combine::apply(&self.ctx.fabric.stats, op, self.dtype.map(), a, b, *count)?;
            }
            Step::PackUser { src, count, dtype, to } => {
                // Pack straight into the arena (perf pass: saves an
                // alloc+copy per pack step — see EXPERIMENTS.md §Perf).
                let mut arena = self.arena.borrow_mut();
                pack_into(dtype.map(), unsafe { src.as_slice() }, *count, &mut arena[to.off..to.off + to.len])?;
                // user→arena→wire is a two-hop path: the arena hop is a
                // CPU staging copy even for contiguous layouts (only the
                // arena→wire move models DMA injection).
                self.ctx.fabric.pool.count_copied(to.len);
            }
            Step::UnpackUser { from, dst, count, dtype } => {
                let arena = self.arena.borrow();
                let wire = &arena[from.off..from.off + from.len];
                unpack(dtype.map(), wire, unsafe { dst.as_slice_mut() }, *count)?;
                self.ctx.fabric.pool.count_copied(from.len);
            }
        }
        Ok(())
    }

    /// Core progression: returns true when the whole schedule completed.
    fn turn(&self) -> Result<bool> {
        if self.done.get() {
            return Ok(true);
        }
        loop {
            // Outstanding transfers of the in-flight round.
            {
                let mut sends = self.outstanding_sends.borrow_mut();
                sends.retain(|&t| !engine::take_send_done(&self.ctx, t));
                if !sends.is_empty() {
                    return Ok(false);
                }
            }
            {
                let mut recvs = self.outstanding_recvs.borrow_mut();
                let mut err = None;
                recvs.retain(|&t| {
                    if engine::recv_done(&self.ctx, t) {
                        if let Some(Err(e)) = engine::take_recv_result(&self.ctx, t) {
                            err = Some(e);
                        }
                        false
                    } else {
                        true
                    }
                });
                if let Some(e) = err {
                    return Err(e);
                }
                if !recvs.is_empty() {
                    return Ok(false);
                }
            }
            let r = self.round.get();
            if r >= self.schedule.rounds.len() {
                self.done.set(true);
                return Ok(true);
            }
            // Post the next round (sends before receives so same-range
            // exchange patterns read before they are overwritten).
            let round = &self.schedule.rounds[r];
            for step in round.iter().filter(|s| matches!(s, Step::Send { .. })) {
                self.exec_step(step)?;
            }
            for step in round.iter().filter(|s| !matches!(s, Step::Send { .. })) {
                self.exec_step(step)?;
            }
            self.round.set(r + 1);
        }
    }
}

impl Drop for CollState {
    /// Recycle the arena into the fabric's buffer pool. If an errored run
    /// left transfers outstanding, cancel what can be cancelled first; a
    /// receive that already matched an RTS has RData inbound targeting
    /// raw pointers into this arena, so in that case the arena is
    /// intentionally leaked — a late delivery then lands in
    /// dead-but-still-allocated memory instead of a recycled live buffer
    /// (or freed memory, which is what dropping the `Vec` risked before).
    fn drop(&mut self) {
        let clean = self.drain_outstanding() && !self.tainted.get();
        let arena = std::mem::take(&mut *self.arena.borrow_mut());
        if clean {
            self.ctx.fabric.pool.give(arena);
        } else {
            std::mem::forget(arena);
        }
    }
}

/// Split two non-overlapping ranges out of the arena.
fn split_ranges<'a>(
    arena: &'a mut [u8],
    a: ArenaRange,
    b: ArenaRange,
) -> Result<(&'a [u8], &'a mut [u8])> {
    if a.off + a.len <= b.off {
        let (lo, hi) = arena.split_at_mut(b.off);
        Ok((&lo[a.off..a.off + a.len], &mut hi[..b.len]))
    } else if b.off + b.len <= a.off {
        let (lo, hi) = arena.split_at_mut(a.off);
        Ok((&hi[..a.len], &mut lo[b.off..b.off + b.len]))
    } else {
        Err(mpi_err!(Intern, "overlapping reduce ranges in schedule"))
    }
}

impl Progressable for CollState {
    fn advance(&self, _ctx: &Rc<RankCtx>) -> Result<bool> {
        if self.finished() {
            self.in_engine.set(false);
            return Ok(true);
        }
        match self.turn() {
            Ok(done) => {
                if done {
                    self.in_engine.set(false);
                }
                Ok(done)
            }
            Err(e) => {
                *self.error.borrow_mut() = Some(e);
                self.in_engine.set(false);
                Ok(true) // finished (with error); surfaced at take_result
            }
        }
    }
}

impl CustomRequest for CollState {
    fn done(&self) -> bool {
        self.finished()
    }

    fn take_status(&self) -> Result<Status> {
        self.take_result().map(|()| Status::empty())
    }
}

/// Run a schedule to completion (the blocking collective entry).
pub fn run_blocking(state: Rc<CollState>) -> Result<()> {
    let ctx = state.ctx.clone();
    state.register_in_engine();
    engine::wait_for(&ctx, || state.finished())?;
    state.take_result()
}

/// Wrap a schedule as a nonblocking request.
pub fn run_nonblocking(state: Rc<CollState>) -> crate::request::Request {
    let ctx = state.ctx.clone();
    state.register_in_engine();
    // Kick it once so single-round local-only schedules complete inline.
    let _ = state.advance(&ctx);
    crate::request::Request::custom(ctx, state)
}
