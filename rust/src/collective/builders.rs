//! Schedule builders: one per collective algorithm.
//!
//! Conventions:
//! * `r` = this process's group rank, `p` = communicator size.
//! * Peers are translated to world ranks here, at build time.
//! * Local steps (pack/copy/reduce) that consume a transfer's data are
//!   placed in a *later* round than the transfer; within a round, the
//!   engine posts sends first, then executes local steps and receive posts
//!   in builder order.
//! * All arena contents are packed wire bytes.

use super::config::{AllgathervAlg, AllreduceAlg, AlltoallvAlg, BcastAlg, ReduceAlg};
use super::schedule::{ArenaRange, SchedBuilder, Schedule};
use super::tuned;
use crate::comm::Comm;
use crate::datatype::Datatype;
use crate::op::Op;
use crate::p2p::{RawBuf, RawBufMut};
use crate::Result;

pub(crate) fn w(comm: &Comm, group_rank: usize) -> usize {
    comm.group().world_rank(group_rank).expect("builder rank in range")
}

pub(crate) fn ceil_log2(p: usize) -> usize {
    (usize::BITS - (p - 1).leading_zeros()) as usize
}

/// Disjoint sub-buffer capture (for at-displacement unpacks). The caller
/// guarantees the (off, len) windows handed out are disjoint and in-bounds.
pub(crate) unsafe fn subbuf_mut(buf: &mut [u8], off: usize, len: usize) -> RawBufMut {
    assert!(off + len <= buf.len(), "sub-buffer out of bounds");
    let slice = std::slice::from_raw_parts_mut(buf.as_mut_ptr().add(off), len);
    RawBufMut::from_slice(slice)
}

pub(crate) fn subbuf(buf: &[u8], off: usize, len: usize) -> RawBuf {
    assert!(off + len <= buf.len(), "sub-buffer out of bounds");
    RawBuf::from_slice(&buf[off..off + len])
}

// ---------------- barrier ----------------

/// Dissemination barrier: ceil(log2 p) rounds of zero-byte exchanges.
pub fn barrier(comm: &Comm) -> Schedule {
    let (r, p) = (comm.rank(), comm.size());
    let mut sb = SchedBuilder::new();
    if p > 1 {
        let zero = sb.alloc(0);
        let mut m = 1;
        while m < p {
            sb.send(w(comm, (r + m) % p), zero);
            sb.recv(w(comm, (r + p - m) % p), zero);
            sb.barrier_round();
            m <<= 1;
        }
    }
    sb.finish()
}

// ---------------- bcast ----------------

pub fn bcast(comm: &Comm, buf: &mut [u8], count: usize, dtype: &Datatype, root: usize, alg: BcastAlg) -> Schedule {
    match alg {
        BcastAlg::Auto => {
            let resolved = tuned::resolve_bcast(comm, dtype.size() * count, alg);
            bcast(comm, buf, count, dtype, root, resolved)
        }
        BcastAlg::Binomial => bcast_binomial(comm, buf, count, dtype, root),
        BcastAlg::Linear => bcast_linear(comm, buf, count, dtype, root),
        BcastAlg::Hier => tuned::bcast_hier(comm, buf, count, dtype, root),
    }
}

/// Binomial-tree broadcast (doubling): after round t, ranks 0..2^(t+1)
/// (in root-relative numbering) hold the data.
fn bcast_binomial(comm: &Comm, buf: &mut [u8], count: usize, dtype: &Datatype, root: usize) -> Schedule {
    let (r, p) = (comm.rank(), comm.size());
    let n = dtype.size() * count;
    let vr = (r + p - root) % p;
    let mut sb = SchedBuilder::new();
    let data = sb.alloc(n);
    if r == root {
        sb.pack_user(buf, count, dtype, data);
        sb.barrier_round();
    }
    for t in 0..ceil_log2(p.max(2)) {
        let m = 1usize << t;
        if m > vr && vr + m < p {
            // I already hold the data: forward.
            sb.send(w(comm, (vr + m + root) % p), data);
            sb.barrier_round();
        } else if (m..2 * m).contains(&vr) {
            sb.recv(w(comm, (vr - m + root) % p), data);
            sb.barrier_round();
        }
    }
    if r != root {
        sb.unpack_user(data, buf, count, dtype);
    }
    sb.finish()
}

/// Flat broadcast: root sends to everyone (the ablation baseline).
fn bcast_linear(comm: &Comm, buf: &mut [u8], count: usize, dtype: &Datatype, root: usize) -> Schedule {
    let (r, p) = (comm.rank(), comm.size());
    let n = dtype.size() * count;
    let mut sb = SchedBuilder::new();
    let data = sb.alloc(n);
    if r == root {
        sb.pack_user(buf, count, dtype, data);
        sb.barrier_round();
        for dst in 0..p {
            if dst != root {
                sb.send(w(comm, dst), data);
            }
        }
    } else {
        sb.recv(w(comm, root), data);
        sb.barrier_round();
        sb.unpack_user(data, buf, count, dtype);
    }
    sb.finish()
}

// ---------------- reduce ----------------

/// Reduce dispatch. Non-commutative ops are always routed to the ordered
/// linear fold by [`tuned::resolve_reduce`]; the `alg` handed in here is
/// expected to be pre-resolved (an `Auto` is resolved again, defensively).
#[allow(clippy::too_many_arguments)]
pub fn reduce(
    comm: &Comm,
    sbuf: Option<&[u8]>,
    rbuf: Option<&mut [u8]>,
    count: usize,
    dtype: &Datatype,
    op: &Op,
    root: usize,
    alg: ReduceAlg,
) -> Result<Schedule> {
    let alg = tuned::resolve_reduce(comm, dtype.size() * count, op.is_commutative(), alg);
    Ok(match alg {
        ReduceAlg::Auto => unreachable!("resolve_reduce returns a concrete algorithm"),
        ReduceAlg::Binomial => reduce_binomial(comm, sbuf, rbuf, count, dtype, root),
        ReduceAlg::Linear => reduce_linear_ordered(comm, sbuf, rbuf, count, dtype, root),
        ReduceAlg::Hier => tuned::reduce_hier(comm, sbuf, rbuf, count, dtype, root),
    })
}

/// `sbuf = None` means MPI_IN_PLACE at the root (contribution is in rbuf).
pub(crate) fn pack_contribution(
    sb: &mut SchedBuilder,
    sbuf: Option<&[u8]>,
    rbuf: &Option<&mut [u8]>,
    count: usize,
    dtype: &Datatype,
    to: ArenaRange,
) {
    match sbuf {
        Some(s) => sb.pack_user(s, count, dtype, to),
        None => {
            let rb = rbuf.as_ref().expect("IN_PLACE requires a receive buffer");
            sb.pack_user_raw(subbuf(rb, 0, rb.len()), count, dtype, to);
        }
    }
}

fn reduce_binomial(
    comm: &Comm,
    sbuf: Option<&[u8]>,
    mut rbuf: Option<&mut [u8]>,
    count: usize,
    dtype: &Datatype,
    root: usize,
) -> Schedule {
    let (r, p) = (comm.rank(), comm.size());
    let n = dtype.size() * count;
    let vr = (r + p - root) % p;
    let mut sb = SchedBuilder::new();
    let acc = sb.alloc(n);
    let tmp = sb.alloc(n);
    pack_contribution(&mut sb, sbuf, &rbuf, count, dtype, acc);
    sb.barrier_round();
    let mut m = 1usize;
    while m < p {
        if vr & m != 0 {
            sb.send(w(comm, (vr - m + root) % p), acc);
            sb.barrier_round();
            break;
        } else if vr + m < p {
            sb.recv(w(comm, (vr + m + root) % p), tmp);
            sb.barrier_round();
            sb.reduce(tmp, acc, count);
            sb.barrier_round();
        }
        m <<= 1;
    }
    if r == root {
        let rb = rbuf.as_mut().expect("root must supply a receive buffer");
        sb.unpack_user(acc, rb, count, dtype);
    }
    sb.finish()
}

/// Ordered reduction: the root receives every contribution and folds them
/// left-to-right (rank 0 first), which is what non-commutative user ops
/// require. `O(p)` messages but semantically exact.
fn reduce_linear_ordered(
    comm: &Comm,
    sbuf: Option<&[u8]>,
    mut rbuf: Option<&mut [u8]>,
    count: usize,
    dtype: &Datatype,
    root: usize,
) -> Schedule {
    let (r, p) = (comm.rank(), comm.size());
    let n = dtype.size() * count;
    let mut sb = SchedBuilder::new();
    if r != root {
        let stage = sb.alloc(n);
        pack_contribution(&mut sb, sbuf, &rbuf, count, dtype, stage);
        sb.barrier_round();
        sb.send(w(comm, root), stage);
    } else {
        // Slot per rank, in rank order.
        let slots: Vec<ArenaRange> = (0..p).map(|_| sb.alloc(n)).collect();
        pack_contribution(&mut sb, sbuf, &rbuf, count, dtype, slots[r]);
        sb.barrier_round();
        for i in 0..p {
            if i != r {
                sb.recv(w(comm, i), slots[i]);
            }
        }
        sb.barrier_round();
        // Fold left→right: acc walks the slot array. apply(input, inout)
        // computes `inout = input OP inout`, so folding slot[i] (left,
        // already-accumulated) into slot[i+1] (right) keeps order.
        for i in 0..p - 1 {
            sb.reduce(slots[i], slots[i + 1], count);
            sb.barrier_round();
        }
        let rb = rbuf.as_mut().expect("root must supply a receive buffer");
        sb.unpack_user(slots[p - 1], rb, count, dtype);
    }
    sb.finish()
}

// ---------------- allreduce ----------------

#[allow(clippy::too_many_arguments)]
pub fn allreduce(
    comm: &Comm,
    sbuf: Option<&[u8]>,
    rbuf: &mut [u8],
    count: usize,
    dtype: &Datatype,
    op: &Op,
    alg: AllreduceAlg,
) -> Schedule {
    let alg = tuned::resolve_allreduce(comm, dtype.size() * count, op.is_commutative(), alg);
    match alg {
        AllreduceAlg::Auto => unreachable!("resolve_allreduce returns a concrete algorithm"),
        AllreduceAlg::RecursiveDoubling => {
            allreduce_recursive_doubling(comm, sbuf, rbuf, count, dtype)
        }
        AllreduceAlg::Ring => allreduce_ring(comm, sbuf, rbuf, count, dtype),
        AllreduceAlg::ReduceBcast => allreduce_reduce_bcast(comm, sbuf, rbuf, count, dtype),
        AllreduceAlg::Hier => tuned::allreduce_hier(comm, sbuf, rbuf, count, dtype),
    }
}

/// Recursive-doubling allreduce rounds over an arbitrary member list
/// (group ranks), with the standard non-power-of-two pre/post phase.
/// `me` is this rank's index into `members`; `acc` holds the local
/// contribution on entry and the full reduction on exit (for every
/// member — non-members must not call this). Shared by the flat
/// algorithm (`members = 0..p`) and the hierarchical one (`members =
/// node leaders`).
pub(crate) fn recursive_doubling_core(
    sb: &mut SchedBuilder,
    comm: &Comm,
    members: &[usize],
    me: usize,
    acc: ArenaRange,
    tmp: ArenaRange,
    count: usize,
) {
    let p = members.len();
    if p <= 1 {
        return;
    }
    let p2 = if p.is_power_of_two() { p } else { 1 << (ceil_log2(p) - 1) };
    let rem = p - p2;
    // Pre-phase: fold odd members of the first 2*rem into their even peers.
    let newrank: isize = if me < 2 * rem {
        if me % 2 == 1 {
            sb.send(w(comm, members[me - 1]), acc);
            sb.barrier_round();
            -1
        } else {
            sb.recv(w(comm, members[me + 1]), tmp);
            sb.barrier_round();
            sb.reduce(tmp, acc, count);
            sb.barrier_round();
            (me / 2) as isize
        }
    } else {
        (me - rem) as isize
    };

    if newrank >= 0 {
        let nr = newrank as usize;
        let real = |x: usize| if x < rem { x * 2 } else { x + rem };
        let mut m = 1usize;
        while m < p2 {
            let partner = members[real(nr ^ m)];
            sb.send(w(comm, partner), acc);
            sb.recv(w(comm, partner), tmp);
            sb.barrier_round();
            sb.reduce(tmp, acc, count);
            sb.barrier_round();
            m <<= 1;
        }
    }

    // Post-phase: evens hand the result back to their odd peers.
    if me < 2 * rem {
        if me % 2 == 0 {
            sb.send(w(comm, members[me + 1]), acc);
        } else {
            sb.recv(w(comm, members[me - 1]), acc);
        }
        sb.barrier_round();
    }
}

/// Recursive doubling with the standard non-power-of-two pre/post phase.
fn allreduce_recursive_doubling(
    comm: &Comm,
    sbuf: Option<&[u8]>,
    rbuf: &mut [u8],
    count: usize,
    dtype: &Datatype,
) -> Schedule {
    let (r, p) = (comm.rank(), comm.size());
    let n = dtype.size() * count;
    let mut sb = SchedBuilder::new();
    let acc = sb.alloc(n);
    let tmp = sb.alloc(n);
    match sbuf {
        Some(s) => sb.pack_user(s, count, dtype, acc),
        None => sb.pack_user_raw(subbuf(rbuf, 0, rbuf.len()), count, dtype, acc),
    }
    sb.barrier_round();
    let members: Vec<usize> = (0..p).collect();
    recursive_doubling_core(&mut sb, comm, &members, r, acc, tmp, count);
    sb.unpack_user(acc, rbuf, count, dtype);
    sb.finish()
}

/// Ring allreduce (reduce-scatter ring + allgather ring): bandwidth-optimal
/// for large messages. Requires count >= p (falls back implicitly via
/// uneven chunking when smaller).
fn allreduce_ring(
    comm: &Comm,
    sbuf: Option<&[u8]>,
    rbuf: &mut [u8],
    count: usize,
    dtype: &Datatype,
) -> Schedule {
    let (r, p) = (comm.rank(), comm.size());
    let esz = dtype.size();
    let n = esz * count;
    let mut sb = SchedBuilder::new();
    let acc = sb.alloc(n);
    let tmp = sb.alloc(n.div_ceil(p.max(1)) + esz); // one chunk
    match sbuf {
        Some(s) => sb.pack_user(s, count, dtype, acc),
        None => sb.pack_user_raw(subbuf(rbuf, 0, rbuf.len()), count, dtype, acc),
    }
    sb.barrier_round();
    if p > 1 {
        // Chunk boundaries in elements.
        let chunk = |i: usize| -> (usize, usize) {
            let base = count / p;
            let extra = count % p;
            let lo = i * base + i.min(extra);
            let hi = lo + base + usize::from(i < extra);
            (lo, hi)
        };
        let range = |i: usize| -> ArenaRange {
            let (lo, hi) = chunk(i);
            ArenaRange { off: acc.off + lo * esz, len: (hi - lo) * esz }
        };
        let right = w(comm, (r + 1) % p);
        let left = w(comm, (r + p - 1) % p);
        // Reduce-scatter ring: after p-1 rounds, chunk (r+1)%p is fully
        // reduced at rank r... we use the orientation where rank r ends
        // owning chunk r.
        for t in 0..p - 1 {
            let send_chunk = (r + p - t) % p;
            let recv_chunk = (r + p - t - 1) % p;
            let rc = range(recv_chunk);
            sb.send(right, range(send_chunk));
            let tmp_r = ArenaRange { off: tmp.off, len: rc.len };
            sb.recv(left, tmp_r);
            sb.barrier_round();
            let elems = rc.len / esz.max(1);
            sb.reduce(tmp_r, rc, elems);
            sb.barrier_round();
        }
        // Allgather ring.
        for t in 0..p - 1 {
            let send_chunk = (r + 1 + p - t) % p;
            let recv_chunk = (r + p - t) % p;
            sb.send(right, range(send_chunk));
            sb.recv(left, range(recv_chunk));
            sb.barrier_round();
        }
    }
    sb.unpack_user(acc, rbuf, count, dtype);
    sb.finish()
}

/// Composition fallback for non-commutative ops: ordered reduce to rank 0,
/// then binomial broadcast of the result.
fn allreduce_reduce_bcast(
    comm: &Comm,
    sbuf: Option<&[u8]>,
    rbuf: &mut [u8],
    count: usize,
    dtype: &Datatype,
) -> Schedule {
    let (r, p) = (comm.rank(), comm.size());
    let n = dtype.size() * count;
    let root = 0usize;
    let mut sb = SchedBuilder::new();

    // --- ordered linear reduce into `res` at root ---
    let res = if r != root {
        let stage = sb.alloc(n);
        match sbuf {
            Some(s) => sb.pack_user(s, count, dtype, stage),
            None => sb.pack_user_raw(subbuf(rbuf, 0, rbuf.len()), count, dtype, stage),
        }
        sb.barrier_round();
        sb.send(w(comm, root), stage);
        sb.barrier_round();
        stage
    } else {
        let slots: Vec<ArenaRange> = (0..p).map(|_| sb.alloc(n)).collect();
        match sbuf {
            Some(s) => sb.pack_user(s, count, dtype, slots[r]),
            None => sb.pack_user_raw(subbuf(rbuf, 0, rbuf.len()), count, dtype, slots[r]),
        }
        sb.barrier_round();
        for i in 0..p {
            if i != r {
                sb.recv(w(comm, i), slots[i]);
            }
        }
        sb.barrier_round();
        for i in 0..p - 1 {
            sb.reduce(slots[i], slots[i + 1], count);
            sb.barrier_round();
        }
        slots[p - 1]
    };

    // --- binomial bcast of `res` from root (vr == r since root == 0) ---
    for t in 0..ceil_log2(p.max(2)) {
        let m = 1usize << t;
        if m > r && r + m < p {
            sb.send(w(comm, r + m), res);
            sb.barrier_round();
        } else if (m..2 * m).contains(&r) {
            sb.recv(w(comm, r - m), res);
            sb.barrier_round();
        }
    }
    sb.unpack_user(res, rbuf, count, dtype);
    sb.finish()
}

// ---------------- gather / scatter ----------------

/// Linear gather with per-rank counts and byte displacements
/// (`MPI_Gatherv`; `MPI_Gather` passes uniform counts/displs).
#[allow(clippy::too_many_arguments)]
pub fn gatherv(
    comm: &Comm,
    sbuf: &[u8],
    scount: usize,
    sdtype: &Datatype,
    rbuf: Option<&mut [u8]>,
    rcounts: &[usize],
    rdispls_bytes: &[usize],
    rdtype: &Datatype,
    root: usize,
) -> Schedule {
    let (r, p) = (comm.rank(), comm.size());
    let mut sb = SchedBuilder::new();
    if r != root {
        let stage = sb.alloc(sdtype.size() * scount);
        sb.pack_user(sbuf, scount, sdtype, stage);
        sb.barrier_round();
        sb.send(w(comm, root), stage);
    } else {
        let rb = rbuf.expect("root must supply a receive buffer");
        let slots: Vec<ArenaRange> = (0..p).map(|i| sb.alloc(rdtype.size() * rcounts[i])).collect();
        sb.pack_user(sbuf, scount, sdtype, slots[r]);
        sb.barrier_round();
        for i in 0..p {
            if i != r {
                sb.recv(w(comm, i), slots[i]);
            }
        }
        sb.barrier_round();
        for i in 0..p {
            let need = rdtype.extent() as usize * rcounts[i].saturating_sub(1)
                + rdtype.map().true_extent() as usize * usize::from(rcounts[i] > 0);
            let dst = unsafe { subbuf_mut(rb, rdispls_bytes[i], need) };
            sb.unpack_user_raw(slots[i], dst, rcounts[i], rdtype);
        }
    }
    sb.finish()
}

/// Linear scatter with per-rank counts and byte displacements
/// (`MPI_Scatterv`).
#[allow(clippy::too_many_arguments)]
pub fn scatterv(
    comm: &Comm,
    sbuf: Option<&[u8]>,
    scounts: &[usize],
    sdispls_bytes: &[usize],
    sdtype: &Datatype,
    rbuf: &mut [u8],
    rcount: usize,
    rdtype: &Datatype,
    root: usize,
) -> Schedule {
    let (r, p) = (comm.rank(), comm.size());
    let mut sb = SchedBuilder::new();
    if r != root {
        let stage = sb.alloc(rdtype.size() * rcount);
        sb.recv(w(comm, root), stage);
        sb.barrier_round();
        sb.unpack_user(stage, rbuf, rcount, rdtype);
    } else {
        let s = sbuf.expect("root must supply a send buffer");
        let slots: Vec<ArenaRange> = (0..p).map(|i| sb.alloc(sdtype.size() * scounts[i])).collect();
        for i in 0..p {
            let need = sdtype.extent() as usize * scounts[i].saturating_sub(1)
                + sdtype.map().true_extent() as usize * usize::from(scounts[i] > 0);
            sb.pack_user_raw(subbuf(s, sdispls_bytes[i], need), scounts[i], sdtype, slots[i]);
        }
        sb.barrier_round();
        for i in 0..p {
            if i != r {
                sb.send(w(comm, i), slots[i]);
            }
        }
        sb.unpack_user(slots[r], rbuf, rcount, rdtype);
    }
    sb.finish()
}

// ---------------- allgather / alltoall ----------------

/// Allgather with per-rank counts (`MPI_Allgatherv`; `MPI_Allgather`
/// passes uniform counts). Dispatches on the selected algorithm: a
/// pipelined neighbor ring, or a single spread round where every pair
/// exchanges blocks directly.
#[allow(clippy::too_many_arguments)]
pub fn allgatherv(
    comm: &Comm,
    sbuf: Option<&[u8]>, // None = IN_PLACE (own block already in rbuf)
    scount: usize,
    sdtype: &Datatype,
    rbuf: &mut [u8],
    rcounts: &[usize],
    rdispls_bytes: &[usize],
    rdtype: &Datatype,
    alg: AllgathervAlg,
) -> Schedule {
    let (r, p) = (comm.rank(), comm.size());
    // Normally pre-resolved by the caller; resolve here only for a
    // direct builder invocation with the knob still on `Auto`.
    let alg = match alg {
        AllgathervAlg::Auto => {
            let block = rdtype.size() * rcounts.iter().copied().max().unwrap_or(0);
            tuned::resolve_allgatherv(comm, block, AllgathervAlg::Auto)
        }
        other => other,
    };
    let mut sb = SchedBuilder::new();
    let slots: Vec<ArenaRange> = (0..p).map(|i| sb.alloc(rdtype.size() * rcounts[i])).collect();
    match sbuf {
        Some(s) => sb.pack_user(s, scount, sdtype, slots[r]),
        None => {
            let need = slot_span(rdtype, rcounts[r]);
            sb.pack_user_raw(subbuf(rbuf, rdispls_bytes[r], need), rcounts[r], rdtype, slots[r]);
        }
    }
    sb.barrier_round();
    if p > 1 {
        match alg {
            AllgathervAlg::Spread => {
                // One round: own block to every peer, every peer's block in.
                for i in 0..p {
                    if i != r {
                        sb.send(w(comm, i), slots[r]);
                    }
                }
                for i in 0..p {
                    if i != r {
                        sb.recv(w(comm, i), slots[i]);
                    }
                }
                sb.barrier_round();
            }
            _ => {
                let right = w(comm, (r + 1) % p);
                let left = w(comm, (r + p - 1) % p);
                for t in 0..p - 1 {
                    let send_slot = (r + p - t) % p;
                    let recv_slot = (r + p - t - 1) % p;
                    sb.send(right, slots[send_slot]);
                    sb.recv(left, slots[recv_slot]);
                    sb.barrier_round();
                }
            }
        }
    }
    for i in 0..p {
        let need = slot_span(rdtype, rcounts[i]);
        let dst = unsafe { subbuf_mut(rbuf, rdispls_bytes[i], need) };
        sb.unpack_user_raw(slots[i], dst, rcounts[i], rdtype);
    }
    sb.finish()
}

fn slot_span(dtype: &Datatype, count: usize) -> usize {
    if count == 0 {
        0
    } else {
        dtype.extent() as usize * (count - 1) + dtype.map().true_extent() as usize
    }
}

/// Alltoall with per-pair counts and byte displacements
/// (`MPI_Alltoallv`; `MPI_Alltoall` passes uniform). Dispatches on the
/// selected algorithm: the rotation (pairwise) schedule — one send+recv
/// per round, `p-1` rounds — or a single spread round posting every
/// transfer at once.
#[allow(clippy::too_many_arguments)]
pub fn alltoallv(
    comm: &Comm,
    sbuf: &[u8],
    scounts: &[usize],
    sdispls_bytes: &[usize],
    sdtype: &Datatype,
    rbuf: &mut [u8],
    rcounts: &[usize],
    rdispls_bytes: &[usize],
    rdtype: &Datatype,
    alg: AlltoallvAlg,
) -> Schedule {
    let (r, p) = (comm.rank(), comm.size());
    // Normally pre-resolved by the caller; resolve here only for a
    // direct builder invocation with the knob still on `Auto`.
    let alg = match alg {
        AlltoallvAlg::Auto => {
            let sblock = scounts.iter().copied().max().unwrap_or(0) * sdtype.size();
            let rblock = rcounts.iter().copied().max().unwrap_or(0) * rdtype.size();
            tuned::resolve_alltoallv(comm, sblock.max(rblock), AlltoallvAlg::Auto)
        }
        other => other,
    };
    let mut sb = SchedBuilder::new();
    let sslots: Vec<ArenaRange> = (0..p).map(|i| sb.alloc(sdtype.size() * scounts[i])).collect();
    let rslots: Vec<ArenaRange> = (0..p).map(|i| sb.alloc(rdtype.size() * rcounts[i])).collect();
    for i in 0..p {
        let need = slot_span(sdtype, scounts[i]);
        sb.pack_user_raw(subbuf(sbuf, sdispls_bytes[i], need), scounts[i], sdtype, sslots[i]);
    }
    sb.barrier_round();
    // Own block.
    if sslots[r].len == rslots[r].len {
        sb.copy(sslots[r], rslots[r]);
    }
    sb.barrier_round();
    match alg {
        AlltoallvAlg::Spread => {
            for t in 1..p {
                let dst = (r + t) % p;
                sb.send(w(comm, dst), sslots[dst]);
            }
            for t in 1..p {
                let src = (r + p - t) % p;
                sb.recv(w(comm, src), rslots[src]);
            }
            sb.barrier_round();
        }
        _ => {
            for t in 1..p {
                let dst = (r + t) % p;
                let src = (r + p - t) % p;
                sb.send(w(comm, dst), sslots[dst]);
                sb.recv(w(comm, src), rslots[src]);
                sb.barrier_round();
            }
        }
    }
    for i in 0..p {
        let need = slot_span(rdtype, rcounts[i]);
        let dst = unsafe { subbuf_mut(rbuf, rdispls_bytes[i], need) };
        sb.unpack_user_raw(rslots[i], dst, rcounts[i], rdtype);
    }
    sb.finish()
}

/// `MPI_Alltoallw`: per-pair datatypes and counts, displacements in bytes.
#[allow(clippy::too_many_arguments)]
pub fn alltoallw(
    comm: &Comm,
    sbuf: &[u8],
    scounts: &[usize],
    sdispls_bytes: &[usize],
    sdtypes: &[Datatype],
    rbuf: &mut [u8],
    rcounts: &[usize],
    rdispls_bytes: &[usize],
    rdtypes: &[Datatype],
) -> Schedule {
    let (r, p) = (comm.rank(), comm.size());
    let mut sb = SchedBuilder::new();
    let sslots: Vec<ArenaRange> =
        (0..p).map(|i| sb.alloc(sdtypes[i].size() * scounts[i])).collect();
    let rslots: Vec<ArenaRange> =
        (0..p).map(|i| sb.alloc(rdtypes[i].size() * rcounts[i])).collect();
    for i in 0..p {
        let need = slot_span(&sdtypes[i], scounts[i]);
        sb.pack_user_raw(subbuf(sbuf, sdispls_bytes[i], need), scounts[i], &sdtypes[i], sslots[i]);
    }
    sb.barrier_round();
    if sslots[r].len == rslots[r].len {
        sb.copy(sslots[r], rslots[r]);
    }
    sb.barrier_round();
    for t in 1..p {
        let dst = (r + t) % p;
        let src = (r + p - t) % p;
        sb.send(w(comm, dst), sslots[dst]);
        sb.recv(w(comm, src), rslots[src]);
        sb.barrier_round();
    }
    for i in 0..p {
        let need = slot_span(&rdtypes[i], rcounts[i]);
        let dst = unsafe { subbuf_mut(rbuf, rdispls_bytes[i], need) };
        sb.unpack_user_raw(rslots[i], dst, rcounts[i], &rdtypes[i]);
    }
    sb.finish()
}

// ---------------- scan / exscan ----------------

/// Inclusive or exclusive prefix reduction; order-correct for
/// non-commutative ops (incoming partials are always the left operand).
pub fn scan(
    comm: &Comm,
    sbuf: Option<&[u8]>,
    rbuf: &mut [u8],
    count: usize,
    dtype: &Datatype,
    exclusive: bool,
) -> Schedule {
    let (r, p) = (comm.rank(), comm.size());
    let n = dtype.size() * count;
    let mut sb = SchedBuilder::new();
    let result = sb.alloc(n);
    let partial = sb.alloc(n);
    let tmp = sb.alloc(n);
    match sbuf {
        Some(s) => sb.pack_user(s, count, dtype, partial),
        None => sb.pack_user_raw(subbuf(rbuf, 0, rbuf.len()), count, dtype, partial),
    }
    if !exclusive {
        sb.copy(partial, result);
    }
    sb.barrier_round();
    let mut m = 1usize;
    let mut first_recv = true;
    while m < p {
        if r + m < p {
            sb.send(w(comm, r + m), partial);
        }
        if r >= m {
            sb.recv(w(comm, r - m), tmp);
            sb.barrier_round();
            // partial = tmp OP partial (tmp from lower ranks = left).
            sb.reduce(tmp, partial, count);
            if exclusive && first_recv {
                sb.copy(tmp, result);
                first_recv = false;
            } else {
                // result = tmp OP result — but careful: `reduce` updates in
                // place; for the exclusive first case we copied instead.
                sb.reduce(tmp, result, count);
            }
            sb.barrier_round();
        } else {
            sb.barrier_round();
        }
        m <<= 1;
    }
    // Rank 0's exclusive-scan result is undefined by the standard; we
    // leave rbuf untouched there.
    if !(exclusive && r == 0) {
        sb.unpack_user(result, rbuf, count, dtype);
    }
    sb.finish()
}

// ---------------- reduce_scatter ----------------

/// Reduce to rank 0 (ordered or binomial per op) followed by scatterv of
/// the reduced vector.
pub fn reduce_scatter(
    comm: &Comm,
    sbuf: Option<&[u8]>,
    rbuf: &mut [u8],
    rcounts: &[usize],
    dtype: &Datatype,
    op: &Op,
) -> Result<Schedule> {
    let (r, p) = (comm.rank(), comm.size());
    let total: usize = rcounts.iter().sum();
    let esz = dtype.size();
    let n = esz * total;
    let root = 0usize;
    let mut sb = SchedBuilder::new();

    // Phase 1: reduce the full vector to root (binomial, commutative; the
    // non-commutative case uses the ordered fold).
    let acc = sb.alloc(n);
    let tmp = sb.alloc(n);
    match sbuf {
        Some(s) => sb.pack_user(s, total, dtype, acc),
        None => sb.pack_user_raw(subbuf(rbuf, 0, rbuf.len()), total, dtype, acc),
    }
    sb.barrier_round();
    if op.is_commutative() {
        let mut m = 1usize;
        while m < p {
            if r & m != 0 {
                sb.send(w(comm, r - m), acc);
                sb.barrier_round();
                break;
            } else if r + m < p {
                sb.recv(w(comm, r + m), tmp);
                sb.barrier_round();
                sb.reduce(tmp, acc, total);
                sb.barrier_round();
            }
            m <<= 1;
        }
    } else {
        // Ordered: everyone ships to root; root folds in rank order.
        if r != root {
            sb.send(w(comm, root), acc);
            sb.barrier_round();
        } else {
            let slots: Vec<ArenaRange> = (0..p).map(|_| sb.alloc(n)).collect();
            sb.copy(acc, slots[0]);
            sb.barrier_round();
            for i in 1..p {
                sb.recv(w(comm, i), slots[i]);
            }
            sb.barrier_round();
            for i in 0..p - 1 {
                sb.reduce(slots[i], slots[i + 1], total);
                sb.barrier_round();
            }
            sb.copy(slots[p - 1], acc);
            sb.barrier_round();
        }
    }

    // Phase 2: scatter chunk i (rcounts[i] elements) to rank i.
    let my_n = esz * rcounts[r];
    let offset_of = |i: usize| -> usize { esz * rcounts[..i].iter().sum::<usize>() };
    if r == root {
        for i in 0..p {
            let chunk = ArenaRange { off: acc.off + offset_of(i), len: esz * rcounts[i] };
            if i == root {
                sb.unpack_user(chunk, rbuf, rcounts[r], dtype);
            } else {
                sb.send(w(comm, i), chunk);
            }
        }
    } else {
        let stage = sb.alloc(my_n);
        sb.recv(w(comm, root), stage);
        sb.barrier_round();
        sb.unpack_user(stage, rbuf, rcounts[r], dtype);
    }
    Ok(sb.finish())
}
