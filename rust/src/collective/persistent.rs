//! Persistent collective operations (MPI-4.0 §6.13): `MPI_Barrier_init`,
//! `MPI_Bcast_init`, `MPI_Allreduce_init` and friends produce a reusable
//! operation *template* that is `start()`-ed once per iteration.
//!
//! The template is a [`CollState`] whose round-based [`Schedule`] is built
//! exactly once: arena, wire-format layout, peer/tag assignments and
//! datatype handles are all fixed at init time. A restart merely rewinds
//! the round counter and re-zeroes the (already allocated) arena, so the
//! per-iteration cost is the communication itself — the "zero-overhead
//! reusable operation template" the modern layer's pipelines build on.
//!
//! Because the schedule is built at init, the algorithm knobs in
//! [`config`](super::config) — including `auto`, resolved through the
//! [`tuned`](super::tuned) decision tables — are consulted exactly once:
//! the template *captures* the resolved algorithm
//! ([`PersistentColl::algorithm`]) and replays it on every restart, no
//! matter how the knobs move in between.
//!
//! Init calls are collective and must be issued in the same order on every
//! rank of the communicator (they consume one collective sequence number,
//! which pins the template's tag block), exactly like the standard's
//! persistent-collective init semantics. Matching across iterations is
//! safe with a fixed tag block because the fabric preserves per-sender
//! FIFO ordering (non-overtaking), so iteration `i`'s transfers match
//! before iteration `i+1`'s.

use super::schedule::CollState;
use crate::p2p::{engine, Status};
use crate::{mpi_err, Result};
use std::cell::Cell;
use std::rc::Rc;

/// A persistent collective operation template.
///
/// Lifecycle: inactive → [`start`](PersistentColl::start) → active →
/// [`wait`](PersistentColl::wait)/[`test`](PersistentColl::test) success →
/// inactive again, restartable. Starting an active template or completing
/// an inactive one is a `Request`-class error, mirroring `MPI_Start`
/// rules.
pub struct PersistentColl {
    state: Rc<CollState>,
    active: Cell<bool>,
    /// Set when an *engine* error (not an operation-level error) escaped
    /// a wait/test: the execution state is unknown, so the template
    /// refuses restarts with a clear error instead of wedging on
    /// "already active".
    poisoned: Cell<bool>,
}

impl PersistentColl {
    pub(crate) fn new(state: Rc<CollState>) -> PersistentColl {
        PersistentColl { state, active: Cell::new(false), poisoned: Cell::new(false) }
    }

    /// Diagnostic label ("barrier", "bcast", "allreduce", ...).
    pub fn name(&self) -> &'static str {
        self.state.name
    }

    /// The concrete algorithm captured at init time ("binomial", "ring",
    /// "hier", ...). An `auto` knob is resolved when the template is
    /// built; later knob writes do not change what a restart runs.
    pub fn algorithm(&self) -> &'static str {
        self.state.alg
    }

    /// Started and not yet completed by `wait`/`test`.
    pub fn is_active(&self) -> bool {
        self.active.get()
    }

    /// `MPI_Start`: activate the template for one more execution. No
    /// allocation happens here — the schedule, arena and datatype handles
    /// are reused as-is.
    pub fn start(&self) -> Result<()> {
        if self.poisoned.get() {
            return Err(mpi_err!(
                Request,
                "persistent {} unusable after an engine error",
                self.state.name
            ));
        }
        if self.active.get() {
            return Err(mpi_err!(
                Request,
                "MPI_Start on an already active persistent {}",
                self.state.name
            ));
        }
        self.state.reset();
        self.state.register_in_engine();
        self.active.set(true);
        let ctx = self.state.rank_ctx().clone();
        // One engine turn so local-only schedules complete inline (and the
        // first round's transfers are posted before the caller blocks).
        // An engine error here leaves the execution state unknown, same
        // as in wait/test: poison the template.
        if let Err(e) = engine::progress(&ctx) {
            self.poisoned.set(true);
            return Err(e);
        }
        Ok(())
    }

    /// Wait for the active execution; the template stays reusable. An
    /// operation-level error (stored by the schedule) completes the
    /// execution and still allows a restart; an error from the engine
    /// itself leaves the execution state unknown and poisons the
    /// template.
    pub fn wait(&self) -> Result<Status> {
        if !self.active.get() {
            return Err(mpi_err!(Request, "wait on inactive persistent {}", self.state.name));
        }
        let ctx = self.state.rank_ctx().clone();
        if let Err(e) = engine::wait_for(&ctx, || self.state.finished()) {
            self.poisoned.set(true);
            return Err(e);
        }
        self.active.set(false);
        self.state.take_result().map(|()| Status::empty())
    }

    /// Nonblocking completion check (`MPI_Test` on the active execution).
    pub fn test(&self) -> Result<Option<Status>> {
        if !self.active.get() {
            return Err(mpi_err!(Request, "test on inactive persistent {}", self.state.name));
        }
        let ctx = self.state.rank_ctx().clone();
        if let Err(e) = engine::progress(&ctx) {
            self.poisoned.set(true);
            return Err(e);
        }
        if self.state.finished() {
            self.active.set(false);
            self.state.take_result().map(|()| Some(Status::empty()))
        } else {
            Ok(None)
        }
    }
}

impl Drop for PersistentColl {
    /// Dropping an active template blocks until the in-flight execution
    /// completes: the schedule holds raw pointers into caller-owned
    /// buffers, so letting the engine keep turning it after those buffers
    /// die would be unsound. (Matches `MPI_Request_free` on an active
    /// persistent request, which also defers destruction to completion.)
    fn drop(&mut self) {
        // While unwinding, skip the blocking wait: a never-completing peer
        // would trip the deadlock watchdog *inside* drop and abort the
        // process, masking the original panic. The engine only progresses
        // on this (dying) thread, so the captured buffers are not touched
        // again either way.
        if self.active.get() && !std::thread::panicking() {
            let ctx = self.state.rank_ctx().clone();
            let _ = engine::wait_for(&ctx, || self.state.finished());
            let _ = self.state.take_result();
        }
    }
}

impl std::fmt::Debug for PersistentColl {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PersistentColl")
            .field("name", &self.state.name)
            .field("active", &self.active.get())
            .finish()
    }
}
