//! Topology-aware tuned collective selection.
//!
//! Production MPIs beat naive bindings not by shaving call overhead but
//! by picking the *right algorithm* per message size and machine shape.
//! This module is that tuning surface, in two halves:
//!
//! 1. **Decision tables** — pure functions (`decide_*`) that map
//!    `(communicator size, nodes spanned, max ranks-per-node, message
//!    bytes)` to a concrete algorithm. Candidates are costed with the
//!    fabric's α–β model ([`NetworkModel::protocol_cost_ns`], which
//!    includes the rendezvous RTS/CTS surcharge above the eager
//!    threshold) and the cheapest wins; ties break toward the first,
//!    latency-safe candidate. Known-pathological choices are never on
//!    the candidate list: a flat linear bcast at `p > 2`, the ordered
//!    reduce+bcast composition for commutative allreduces, hierarchical
//!    variants on a single node.
//! 2. **Hierarchical (node-aware) schedules** — `bcast`, `allreduce` and
//!    `reduce` variants that split a communicator via the fabric's
//!    [`NodeMap`](crate::transport::NodeMap) into per-node rank sets with
//!    one *leader* each: payloads cross the (expensive) inter-node fabric
//!    only between leaders, while everything else rides intra-node links.
//!    The schedules reuse the ordinary round/arena machinery in
//!    [`schedule`](super::schedule), so they pool wire buffers, run
//!    blocking or nonblocking, and persist (`*_init`) like every other
//!    collective.
//!
//! [`resolve_bcast`] and friends glue the two halves to the knobs in
//! [`config`](super::config): an explicit knob value passes through
//! (after correctness fix-ups — non-commutative reductions always take
//! the order-exact path), `Auto` consults the decision table. Resolution
//! happens at *schedule build time*, which is why persistent collectives
//! capture the algorithm at init and replay it regardless of later knob
//! writes.
//!
//! Correctness note: the hierarchical reductions fold contributions in
//! node order rather than rank order, so they are only selected (and only
//! valid) for commutative operations. For integer ops the result is
//! byte-identical to the flat algorithms — pinned by
//! `tests/test_tuned.rs` across 1×N, N×1, uneven and single-rank-node
//! shapes.

use super::builders::{ceil_log2, pack_contribution, recursive_doubling_core, subbuf, w};
use super::config::{AllgathervAlg, AllreduceAlg, AlltoallvAlg, BcastAlg, ReduceAlg};
use super::schedule::{ArenaRange, SchedBuilder, Schedule};
use crate::comm::Comm;
use crate::datatype::Datatype;
use crate::transport::NetworkModel;
use std::collections::BTreeMap;

// ---------------- topology summary ----------------

/// How a communicator's ranks sit on the simulated cluster — the shape
/// key of every decision table.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CommTopo {
    /// Communicator size.
    pub p: usize,
    /// Distinct nodes the group spans.
    pub nodes: usize,
    /// Largest number of group ranks on any single node.
    pub max_ppn: usize,
}

/// Derive the topology summary for `comm` from the fabric's `NodeMap`.
/// Sub-communicators may populate nodes unevenly (or leave some with a
/// single, leader-only rank); this summary reflects the group, not the
/// world. The `O(p)` walk runs once per communicator — the result is
/// memoized on the `Comm` (its group and node map are immutable), so the
/// per-call cost of an `auto` knob is a cache read.
pub fn comm_topo(comm: &Comm) -> CommTopo {
    if let Some((nodes, max_ppn)) = comm.topo_cache.get() {
        return CommTopo { p: comm.size(), nodes, max_ppn };
    }
    let map = &comm.rank_ctx().fabric.nodemap;
    let mut per_node: BTreeMap<usize, usize> = BTreeMap::new();
    for i in 0..comm.size() {
        *per_node.entry(map.node_of(w(comm, i))).or_insert(0) += 1;
    }
    let topo = CommTopo {
        p: comm.size(),
        nodes: per_node.len(),
        max_ppn: per_node.values().copied().max().unwrap_or(1),
    };
    comm.topo_cache.set(Some((topo.nodes, topo.max_ppn)));
    topo
}

fn model(comm: &Comm) -> NetworkModel {
    comm.rank_ctx().fabric.model
}

// ---------------- cost estimates ----------------

/// Critical-path estimate of one candidate bcast algorithm, in modeled ns.
/// Coarse by design: round count × per-round message cost, charging the
/// worst-case (inter-node) link whenever the communicator spans nodes.
fn est_bcast(alg: BcastAlg, t: CommTopo, bytes: usize, m: &NetworkModel) -> f64 {
    let single = t.nodes == 1;
    match alg {
        BcastAlg::Binomial => ceil_log2(t.p.max(2)) as f64 * m.protocol_cost_ns(bytes, single),
        BcastAlg::Linear => (t.p - 1) as f64 * m.protocol_cost_ns(bytes, single),
        BcastAlg::Hier => {
            let inter = if t.nodes > 1 { ceil_log2(t.nodes) } else { 0 };
            inter as f64 * m.protocol_cost_ns(bytes, false)
                + (t.max_ppn - 1) as f64 * m.protocol_cost_ns(bytes, true)
        }
        BcastAlg::Auto => f64::INFINITY,
    }
}

/// Critical-path estimate of one candidate allreduce algorithm.
fn est_allreduce(alg: AllreduceAlg, t: CommTopo, bytes: usize, m: &NetworkModel) -> f64 {
    let single = t.nodes == 1;
    match alg {
        AllreduceAlg::RecursiveDoubling => {
            ceil_log2(t.p.max(2)) as f64 * m.protocol_cost_ns(bytes, single)
        }
        AllreduceAlg::Ring => {
            let chunk = bytes.div_ceil(t.p.max(1));
            (2 * (t.p - 1)) as f64 * m.protocol_cost_ns(chunk, single)
        }
        AllreduceAlg::ReduceBcast => {
            ((t.p - 1) + ceil_log2(t.p.max(2))) as f64 * m.protocol_cost_ns(bytes, single)
        }
        AllreduceAlg::Hier => {
            let inter = if t.nodes > 1 { ceil_log2(t.nodes) } else { 0 };
            inter as f64 * m.protocol_cost_ns(bytes, false)
                + (2 * (t.max_ppn - 1)) as f64 * m.protocol_cost_ns(bytes, true)
        }
        AllreduceAlg::Auto => f64::INFINITY,
    }
}

/// Critical-path estimate of one candidate reduce algorithm.
fn est_reduce(alg: ReduceAlg, t: CommTopo, bytes: usize, m: &NetworkModel) -> f64 {
    let single = t.nodes == 1;
    match alg {
        ReduceAlg::Binomial => ceil_log2(t.p.max(2)) as f64 * m.protocol_cost_ns(bytes, single),
        ReduceAlg::Linear => (t.p - 1) as f64 * m.protocol_cost_ns(bytes, single),
        ReduceAlg::Hier => {
            let inter = if t.nodes > 1 { ceil_log2(t.nodes) } else { 0 };
            inter as f64 * m.protocol_cost_ns(bytes, false)
                + (t.max_ppn - 1) as f64 * m.protocol_cost_ns(bytes, true)
        }
        ReduceAlg::Auto => f64::INFINITY,
    }
}

// ---------------- decision tables ----------------

fn argmin<T: Copy>(candidates: &[(T, f64)]) -> T {
    let mut best = candidates[0];
    for &c in &candidates[1..] {
        if c.1 < best.1 {
            best = c;
        }
    }
    best.0
}

/// Auto table for bcast. Candidates: binomial always; hierarchical when
/// the communicator spans several nodes *and* some node holds more than
/// one rank (otherwise it degenerates to binomial-over-everyone). Linear
/// is never auto-selected — at `p > 2` it serializes `p-1` sends at the
/// root, and at `p ≤ 2` it ties binomial.
pub fn decide_bcast(t: CommTopo, bytes: usize, m: &NetworkModel) -> BcastAlg {
    if t.p <= 1 {
        return BcastAlg::Binomial;
    }
    let mut cand = vec![(BcastAlg::Binomial, est_bcast(BcastAlg::Binomial, t, bytes, m))];
    if t.nodes > 1 && t.max_ppn > 1 {
        cand.push((BcastAlg::Hier, est_bcast(BcastAlg::Hier, t, bytes, m)));
    }
    argmin(&cand)
}

/// Auto table for (commutative) allreduce. Candidates: recursive
/// doubling always; ring at `p > 2` (bandwidth regime); hierarchical on
/// genuinely hierarchical shapes. The ordered reduce+bcast composition
/// is never auto-selected for commutative ops — it exists for
/// correctness on non-commutative ones (see [`resolve_allreduce`]).
pub fn decide_allreduce(t: CommTopo, bytes: usize, m: &NetworkModel) -> AllreduceAlg {
    if t.p <= 1 {
        return AllreduceAlg::RecursiveDoubling;
    }
    let mut cand = vec![(
        AllreduceAlg::RecursiveDoubling,
        est_allreduce(AllreduceAlg::RecursiveDoubling, t, bytes, m),
    )];
    if t.p > 2 {
        cand.push((AllreduceAlg::Ring, est_allreduce(AllreduceAlg::Ring, t, bytes, m)));
    }
    if t.nodes > 1 && t.max_ppn > 1 {
        cand.push((AllreduceAlg::Hier, est_allreduce(AllreduceAlg::Hier, t, bytes, m)));
    }
    argmin(&cand)
}

/// Auto table for (commutative) reduce: binomial vs hierarchical. The
/// ordered linear fold is never auto-selected — it is the forced,
/// order-exact path for non-commutative ops (see [`resolve_reduce`]).
pub fn decide_reduce(t: CommTopo, bytes: usize, m: &NetworkModel) -> ReduceAlg {
    if t.p <= 1 {
        return ReduceAlg::Binomial;
    }
    let mut cand = vec![(ReduceAlg::Binomial, est_reduce(ReduceAlg::Binomial, t, bytes, m))];
    if t.nodes > 1 && t.max_ppn > 1 {
        cand.push((ReduceAlg::Hier, est_reduce(ReduceAlg::Hier, t, bytes, m)));
    }
    argmin(&cand)
}

/// Auto table for allgather(v), keyed on the largest per-rank block:
/// eager-sized blocks take the single spread round (one latency instead
/// of `p-1`), rendezvous-sized blocks take the pipelined ring, which
/// bounds in-flight data to one block per link.
pub fn decide_allgatherv(p: usize, block_bytes: usize, m: &NetworkModel) -> AllgathervAlg {
    if p <= 2 || m.is_eager(block_bytes) {
        AllgathervAlg::Spread
    } else {
        AllgathervAlg::Ring
    }
}

/// Auto table for alltoall(v), same reasoning as [`decide_allgatherv`]
/// with the rotation (pairwise) schedule as the rendezvous-regime choice.
pub fn decide_alltoallv(p: usize, block_bytes: usize, m: &NetworkModel) -> AlltoallvAlg {
    if p <= 2 || m.is_eager(block_bytes) {
        AlltoallvAlg::Spread
    } else {
        AlltoallvAlg::Pairwise
    }
}

// ---------------- collective-IO aggregator planning ----------------

/// Auto table for the two-phase collective-IO exchange: how many
/// aggregator ranks collect stripes on behalf of the communicator.
/// Roughly one per node — the exchange exists to replace many small
/// strided file ops with few large contiguous ones, and per-node
/// aggregation removes the inter-node hop for everyone sharing a node —
/// but never more than the stripe count (an aggregator owning zero
/// stripes is pure overhead) and never more than the communicator size.
/// Always at least one, so a degenerate span still has an owner.
pub fn decide_io_aggregators(t: CommTopo, stripe_bytes: usize, total_bytes: usize) -> usize {
    let stripes = total_bytes.div_ceil(stripe_bytes.max(1)).max(1);
    t.nodes.clamp(1, t.p.max(1)).min(stripes)
}

// ---------------- chunked-reduction planning ----------------

/// Modeled combine throughput used to cost the chunked pipeline,
/// ns per payload byte. The fabric's α–β model prices transfers but not
/// compute; this constant stands in for the combine kernels' block rate
/// so the chunking decision has both sides of the overlap to compare.
pub const COMBINE_NS_PER_BYTE: f64 = 0.5;

/// How a large reduction payload is split for the chunked,
/// compute-overlapped pipeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChunkPlan {
    /// Elements per chunk — always a multiple of the combine kernels'
    /// [`BLOCK`](super::combine::BLOCK) (4096), so only the final tail
    /// chunk can be partial.
    pub chunk_elems: usize,
    /// Total chunks (≥ 2 — a one-chunk plan is just the unchunked path).
    pub nchunks: usize,
}

/// Pure chunk sizing: target about a quarter of the payload per chunk so
/// the pipeline is at least 4 deep, clamped to [1, 8] combine blocks and
/// rounded to a whole block. Returns `None` when the payload doesn't
/// yield at least two chunks.
pub fn plan_chunks(count: usize) -> Option<ChunkPlan> {
    const BLOCK: usize = super::combine::BLOCK;
    let target = (count / 4).clamp(BLOCK, 8 * BLOCK);
    let chunk_elems = (target / BLOCK).max(1) * BLOCK;
    let nchunks = count.div_ceil(chunk_elems);
    if nchunks < 2 {
        return None;
    }
    Some(ChunkPlan { chunk_elems, nchunks })
}

/// The α side of the chunking trade: splitting an `r`-round schedule
/// into chunks multiplies the per-message latency by the chunk count, so
/// chunking only pays when the combine work hidden per chunk exceeds the
/// extra latency per chunk. Pure so the boundary is unit-testable.
pub fn chunking_pays(chunk_bytes: usize, rounds: usize, single_node: bool, m: &NetworkModel) -> bool {
    COMBINE_NS_PER_BYTE * chunk_bytes as f64 > rounds as f64 * m.protocol_cost_ns(0, single_node)
}

/// Decide whether (and how) to run an allreduce through the chunked
/// pipeline. `None` = take the ordinary unchunked path. Gates, in order:
///
/// * the op/layout must be in the chunkable fast set
///   ([`combine::chunk_eligible`](super::combine) — predefined
///   commutative sum/prod/max/min over contiguous uniform
///   f32/f64/i32/i64); user and non-commutative ops always take the
///   unchunked order-exact path, extending [`resolve_allreduce`]'s
///   forcing;
/// * the payload must reach the `FERROMPI_COMBINE_CHUNK` threshold and
///   split into ≥ 2 chunks;
/// * the algorithm knob must resolve to a *chunk-invariant* schedule:
///   recursive doubling (pinned for `auto`) or reduce+bcast pair ranks
///   by topology alone, so folding per chunk is byte-identical to the
///   whole-payload fold. Ring reduce-scatters at `count/p` boundaries
///   and hierarchical folds depend on leader buffering — forcing either
///   knob disables chunking rather than change answers;
/// * the α–β model must say the hidden combine time beats the added
///   per-chunk latency ([`chunking_pays`]).
pub fn resolve_allreduce_chunking(
    comm: &Comm,
    count: usize,
    dtype: &Datatype,
    op: &crate::op::Op,
) -> Option<(AllreduceAlg, ChunkPlan)> {
    let t = comm_topo(comm);
    if t.p < 2 || !super::combine::chunk_eligible(op, dtype.map()) {
        return None;
    }
    let bytes = dtype.size() * count;
    if bytes < super::config::chunk_threshold() {
        return None;
    }
    let alg = match super::config::allreduce_alg() {
        AllreduceAlg::Auto | AllreduceAlg::RecursiveDoubling => AllreduceAlg::RecursiveDoubling,
        AllreduceAlg::ReduceBcast => AllreduceAlg::ReduceBcast,
        AllreduceAlg::Ring | AllreduceAlg::Hier => return None,
    };
    let plan = plan_chunks(count)?;
    let rounds = ceil_log2(t.p.max(2));
    let chunk_bytes = plan.chunk_elems * dtype.size();
    if !chunking_pays(chunk_bytes, rounds, t.nodes == 1, &model(comm)) {
        return None;
    }
    Some((alg, plan))
}

/// [`resolve_allreduce_chunking`]'s rooted-reduce sibling. The
/// chunk-invariant schedules here are binomial (pinned for `auto`) and
/// the ordered linear fold — both pair ranks by topology alone;
/// hierarchical is excluded as above.
pub fn resolve_reduce_chunking(
    comm: &Comm,
    count: usize,
    dtype: &Datatype,
    op: &crate::op::Op,
) -> Option<(ReduceAlg, ChunkPlan)> {
    let t = comm_topo(comm);
    if t.p < 2 || !super::combine::chunk_eligible(op, dtype.map()) {
        return None;
    }
    let bytes = dtype.size() * count;
    if bytes < super::config::chunk_threshold() {
        return None;
    }
    let alg = match super::config::reduce_alg() {
        ReduceAlg::Auto | ReduceAlg::Binomial => ReduceAlg::Binomial,
        ReduceAlg::Linear => ReduceAlg::Linear,
        ReduceAlg::Hier => return None,
    };
    let plan = plan_chunks(count)?;
    let rounds = ceil_log2(t.p.max(2));
    let chunk_bytes = plan.chunk_elems * dtype.size();
    if !chunking_pays(chunk_bytes, rounds, t.nodes == 1, &model(comm)) {
        return None;
    }
    Some((alg, plan))
}

// ---------------- knob → concrete resolution ----------------

/// Resolve the bcast knob to a concrete algorithm for a `bytes`-sized
/// payload on `comm`.
pub fn resolve_bcast(comm: &Comm, bytes: usize, knob: BcastAlg) -> BcastAlg {
    match knob {
        BcastAlg::Auto => decide_bcast(comm_topo(comm), bytes, &model(comm)),
        other => other,
    }
}

/// Resolve the allreduce knob. Non-commutative ops are *always* routed to
/// the ordered reduce+bcast composition, whatever the knob says — every
/// other algorithm reassociates/commutes the fold.
pub fn resolve_allreduce(
    comm: &Comm,
    bytes: usize,
    commutative: bool,
    knob: AllreduceAlg,
) -> AllreduceAlg {
    if !commutative {
        return AllreduceAlg::ReduceBcast;
    }
    match knob {
        AllreduceAlg::Auto => decide_allreduce(comm_topo(comm), bytes, &model(comm)),
        other => other,
    }
}

/// Resolve the reduce knob. Non-commutative ops always take the ordered
/// linear fold (rank order is the only order the standard permits).
pub fn resolve_reduce(comm: &Comm, bytes: usize, commutative: bool, knob: ReduceAlg) -> ReduceAlg {
    if !commutative {
        return ReduceAlg::Linear;
    }
    match knob {
        ReduceAlg::Auto => decide_reduce(comm_topo(comm), bytes, &model(comm)),
        other => other,
    }
}

/// Resolve the allgatherv knob (`block_bytes` = largest per-rank block).
pub fn resolve_allgatherv(comm: &Comm, block_bytes: usize, knob: AllgathervAlg) -> AllgathervAlg {
    match knob {
        AllgathervAlg::Auto => decide_allgatherv(comm.size(), block_bytes, &model(comm)),
        other => other,
    }
}

/// Resolve the alltoallv knob (`block_bytes` = largest per-pair block).
pub fn resolve_alltoallv(comm: &Comm, block_bytes: usize, knob: AlltoallvAlg) -> AlltoallvAlg {
    match knob {
        AlltoallvAlg::Auto => decide_alltoallv(comm.size(), block_bytes, &model(comm)),
        other => other,
    }
}

/// What the current knobs resolve to for a `bytes`-sized payload on a
/// communicator — the introspection surface behind
/// [`Communicator::algorithm_selection`](crate::modern::Communicator::algorithm_selection).
/// Reductions are resolved for the commutative case.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Selection {
    pub bcast: BcastAlg,
    pub allreduce: AllreduceAlg,
    pub reduce: ReduceAlg,
    pub allgatherv: AllgathervAlg,
    pub alltoallv: AlltoallvAlg,
}

/// Resolve every knob for `bytes` on `comm` (see [`Selection`]).
pub fn selection_for(comm: &Comm, bytes: usize) -> Selection {
    use super::config;
    Selection {
        bcast: resolve_bcast(comm, bytes, config::bcast_alg()),
        allreduce: resolve_allreduce(comm, bytes, true, config::allreduce_alg()),
        reduce: resolve_reduce(comm, bytes, true, config::reduce_alg()),
        allgatherv: resolve_allgatherv(comm, bytes, config::allgatherv_alg()),
        alltoallv: resolve_alltoallv(comm, bytes, config::alltoallv_alg()),
    }
}

// ---------------- hierarchical schedules ----------------

/// Per-node leader decomposition of a communicator. All ranks are
/// *group* ranks; `leaders` is ordered by node id, so every rank derives
/// the identical structure.
struct HierLayout {
    /// One leader per represented node, in node-id order.
    leaders: Vec<usize>,
    /// Group ranks on this rank's node (ascending; includes the leader).
    local: Vec<usize>,
    /// This rank's node leader.
    my_leader: usize,
    /// Index of this rank's node in `leaders`.
    my_leader_idx: usize,
    /// Index of the root's node in `leaders` (0 when rootless).
    root_leader_idx: usize,
}

impl HierLayout {
    fn is_leader(&self, r: usize) -> bool {
        self.my_leader == r
    }

    /// Group ranks sharing this rank's node, excluding `r` itself.
    fn local_peers(&self, r: usize) -> Vec<usize> {
        self.local.iter().copied().filter(|&x| x != r).collect()
    }
}

/// Build the leader decomposition. With a root, the root is its own
/// node's leader (so rooted trees start and end at the root without an
/// extra hop); other nodes elect their lowest group rank. Nodes holding a
/// single rank are led by that rank — the intra-node phases degenerate to
/// no-ops there.
fn hier_layout(comm: &Comm, root: Option<usize>) -> HierLayout {
    let map = &comm.rank_ctx().fabric.nodemap;
    let mut nodes: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
    for i in 0..comm.size() {
        nodes.entry(map.node_of(w(comm, i))).or_default().push(i);
    }
    let r = comm.rank();
    let my_node = map.node_of(w(comm, r));
    let root_node = root.map(|rt| map.node_of(w(comm, rt)));
    let mut lay = HierLayout {
        leaders: Vec::with_capacity(nodes.len()),
        local: Vec::new(),
        my_leader: r,
        my_leader_idx: 0,
        root_leader_idx: 0,
    };
    for (idx, (node, ranks)) in nodes.iter().enumerate() {
        let leader = match root {
            Some(rt) if Some(*node) == root_node => rt,
            _ => ranks[0],
        };
        lay.leaders.push(leader);
        if *node == my_node {
            lay.my_leader = leader;
            lay.my_leader_idx = idx;
            lay.local = ranks.clone();
        }
        if Some(*node) == root_node {
            lay.root_leader_idx = idx;
        }
    }
    lay
}

/// Node-aware broadcast: binomial tree over node leaders (rooted at the
/// root, which leads its own node), then a leader → local-ranks fan-out
/// over intra-node links. Inter-node traffic is `O(log nodes)` messages
/// instead of the flat tree's worst-case `O(log p)`.
pub fn bcast_hier(comm: &Comm, buf: &mut [u8], count: usize, dtype: &Datatype, root: usize) -> Schedule {
    let r = comm.rank();
    let n = dtype.size() * count;
    let lay = hier_layout(comm, Some(root));
    let mut sb = SchedBuilder::new();
    let data = sb.alloc(n);
    if r == root {
        sb.pack_user(buf, count, dtype, data);
        sb.barrier_round();
    }
    if lay.is_leader(r) {
        // Inter-node binomial over leaders, root's node first.
        let l = lay.leaders.len();
        let vr = (lay.my_leader_idx + l - lay.root_leader_idx) % l;
        for t in 0..ceil_log2(l.max(2)) {
            let m = 1usize << t;
            if m > vr && vr + m < l {
                let peer = lay.leaders[(vr + m + lay.root_leader_idx) % l];
                sb.send(w(comm, peer), data);
                sb.barrier_round();
            } else if (m..2 * m).contains(&vr) {
                let peer = lay.leaders[(vr - m + lay.root_leader_idx) % l];
                sb.recv(w(comm, peer), data);
                sb.barrier_round();
            }
        }
        // Intra-node fan-out.
        for peer in lay.local_peers(r) {
            sb.send(w(comm, peer), data);
        }
        sb.barrier_round();
    } else {
        sb.recv(w(comm, lay.my_leader), data);
        sb.barrier_round();
    }
    if r != root {
        sb.unpack_user(data, buf, count, dtype);
    }
    sb.finish()
}

/// Node-aware allreduce (commutative ops only — see the module docs):
/// local ranks fold into their node leader, leaders run recursive
/// doubling across nodes, leaders fan the result back out. The full
/// vector crosses inter-node links `O(log nodes)` times per leader
/// instead of riding every round of a flat exchange.
pub fn allreduce_hier(
    comm: &Comm,
    sbuf: Option<&[u8]>,
    rbuf: &mut [u8],
    count: usize,
    dtype: &Datatype,
) -> Schedule {
    let r = comm.rank();
    let n = dtype.size() * count;
    let lay = hier_layout(comm, None);
    let mut sb = SchedBuilder::new();
    let acc = sb.alloc(n);
    let tmp = sb.alloc(n);
    match sbuf {
        Some(s) => sb.pack_user(s, count, dtype, acc),
        None => sb.pack_user_raw(subbuf(rbuf, 0, rbuf.len()), count, dtype, acc),
    }
    sb.barrier_round();
    if lay.is_leader(r) {
        let peers = lay.local_peers(r);
        if !peers.is_empty() {
            // Gather local contributions in parallel, fold serially.
            let slots: Vec<ArenaRange> = peers.iter().map(|_| sb.alloc(n)).collect();
            for (i, &peer) in peers.iter().enumerate() {
                sb.recv(w(comm, peer), slots[i]);
            }
            sb.barrier_round();
            for &slot in &slots {
                sb.reduce(slot, acc, count);
            }
            sb.barrier_round();
        }
        recursive_doubling_core(&mut sb, comm, &lay.leaders, lay.my_leader_idx, acc, tmp, count);
        for &peer in &peers {
            sb.send(w(comm, peer), acc);
        }
        sb.barrier_round();
    } else {
        sb.send(w(comm, lay.my_leader), acc);
        sb.barrier_round();
        sb.recv(w(comm, lay.my_leader), acc);
        sb.barrier_round();
    }
    sb.unpack_user(acc, rbuf, count, dtype);
    sb.finish()
}

/// Node-aware reduce (commutative ops only): local ranks fold into their
/// node leader, leaders run a binomial reduction toward the root (which
/// leads its own node, so the result lands without an extra hop).
pub fn reduce_hier(
    comm: &Comm,
    sbuf: Option<&[u8]>,
    mut rbuf: Option<&mut [u8]>,
    count: usize,
    dtype: &Datatype,
    root: usize,
) -> Schedule {
    let r = comm.rank();
    let n = dtype.size() * count;
    let lay = hier_layout(comm, Some(root));
    let mut sb = SchedBuilder::new();
    let acc = sb.alloc(n);
    let tmp = sb.alloc(n);
    pack_contribution(&mut sb, sbuf, &rbuf, count, dtype, acc);
    sb.barrier_round();
    if lay.is_leader(r) {
        let peers = lay.local_peers(r);
        if !peers.is_empty() {
            let slots: Vec<ArenaRange> = peers.iter().map(|_| sb.alloc(n)).collect();
            for (i, &peer) in peers.iter().enumerate() {
                sb.recv(w(comm, peer), slots[i]);
            }
            sb.barrier_round();
            for &slot in &slots {
                sb.reduce(slot, acc, count);
            }
            sb.barrier_round();
        }
        // Binomial over leaders toward the root's node.
        let l = lay.leaders.len();
        let vr = (lay.my_leader_idx + l - lay.root_leader_idx) % l;
        let mut m = 1usize;
        while m < l {
            if vr & m != 0 {
                let peer = lay.leaders[(vr - m + lay.root_leader_idx) % l];
                sb.send(w(comm, peer), acc);
                sb.barrier_round();
                break;
            } else if vr + m < l {
                let peer = lay.leaders[(vr + m + lay.root_leader_idx) % l];
                sb.recv(w(comm, peer), tmp);
                sb.barrier_round();
                sb.reduce(tmp, acc, count);
                sb.barrier_round();
            }
            m <<= 1;
        }
        if r == root {
            let rb = rbuf.as_mut().expect("root must supply a receive buffer");
            sb.unpack_user(acc, rb, count, dtype);
        }
    } else {
        sb.send(w(comm, lay.my_leader), acc);
        sb.barrier_round();
    }
    sb.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn omnipath() -> NetworkModel {
        NetworkModel::omnipath()
    }

    fn topo(p: usize, nodes: usize, max_ppn: usize) -> CommTopo {
        CommTopo { p, nodes, max_ppn }
    }

    #[test]
    fn allreduce_table_boundaries() {
        let m = omnipath();
        // Multi-node, small payload: hierarchical wins (fewer inter hops).
        assert_eq!(decide_allreduce(topo(8, 4, 2), 64, &m), AllreduceAlg::Hier);
        // Multi-node, huge payload: ring's chunking wins on bandwidth.
        assert_eq!(decide_allreduce(topo(8, 4, 2), 4 << 20, &m), AllreduceAlg::Ring);
        // Single node, small: recursive doubling (hier not a candidate).
        assert_eq!(decide_allreduce(topo(8, 1, 8), 64, &m), AllreduceAlg::RecursiveDoubling);
        // Single node, large: ring.
        assert_eq!(decide_allreduce(topo(8, 1, 8), 1 << 20, &m), AllreduceAlg::Ring);
        // Degenerate communicators stay latency-safe.
        assert_eq!(decide_allreduce(topo(1, 1, 1), 1 << 20, &m), AllreduceAlg::RecursiveDoubling);
        assert_eq!(decide_allreduce(topo(2, 2, 1), 64, &m), AllreduceAlg::RecursiveDoubling);
    }

    #[test]
    fn bcast_table_boundaries() {
        let m = omnipath();
        assert_eq!(decide_bcast(topo(8, 4, 2), 1024, &m), BcastAlg::Hier);
        assert_eq!(decide_bcast(topo(8, 1, 8), 1024, &m), BcastAlg::Binomial);
        // One rank per node: hier degenerates, binomial is kept.
        assert_eq!(decide_bcast(topo(4, 4, 1), 1024, &m), BcastAlg::Binomial);
        assert_eq!(decide_bcast(topo(2, 1, 2), 64, &m), BcastAlg::Binomial);
        assert_eq!(decide_bcast(topo(1, 1, 1), 0, &m), BcastAlg::Binomial);
    }

    #[test]
    fn reduce_table_boundaries() {
        let m = omnipath();
        assert_eq!(decide_reduce(topo(8, 4, 2), 64, &m), ReduceAlg::Hier);
        assert_eq!(decide_reduce(topo(8, 1, 8), 64, &m), ReduceAlg::Binomial);
        assert_eq!(decide_reduce(topo(4, 4, 1), 1 << 16, &m), ReduceAlg::Binomial);
    }

    #[test]
    fn v_collectives_switch_at_the_eager_threshold() {
        let m = omnipath();
        let at = m.eager_threshold;
        assert_eq!(decide_allgatherv(8, at, &m), AllgathervAlg::Spread);
        assert_eq!(decide_allgatherv(8, at + 1, &m), AllgathervAlg::Ring);
        assert_eq!(decide_alltoallv(8, at, &m), AlltoallvAlg::Spread);
        assert_eq!(decide_alltoallv(8, at + 1, &m), AlltoallvAlg::Pairwise);
        // Tiny communicators always spread: a ring/rotation buys nothing.
        assert_eq!(decide_allgatherv(2, at + 1, &m), AllgathervAlg::Spread);
        assert_eq!(decide_alltoallv(2, at + 1, &m), AlltoallvAlg::Spread);
    }

    /// The acceptance sweep: across shapes and sizes (including both
    /// sides of the eager threshold) auto never lands on a pathological
    /// choice.
    #[test]
    fn auto_is_never_pathological() {
        let m = omnipath();
        let e = m.eager_threshold;
        let shapes = [
            topo(1, 1, 1),
            topo(2, 1, 2),
            topo(2, 2, 1),
            topo(4, 2, 2),
            topo(8, 4, 2),
            topo(8, 1, 8),
            topo(8, 8, 1),
            topo(12, 4, 3),
            topo(5, 2, 3), // uneven ppn
            topo(32, 16, 2),
        ];
        let sizes = [0usize, 1, 64, e - 1, e, e + 1, 1 << 20, 16 << 20];
        for t in shapes {
            for &bytes in &sizes {
                let b = decide_bcast(t, bytes, &m);
                assert_ne!(b, BcastAlg::Auto);
                assert_ne!(b, BcastAlg::Linear, "linear bcast at {t:?}/{bytes}");
                if t.nodes == 1 || t.max_ppn == 1 {
                    assert_ne!(b, BcastAlg::Hier, "degenerate hier bcast at {t:?}/{bytes}");
                }
                let a = decide_allreduce(t, bytes, &m);
                assert_ne!(a, AllreduceAlg::Auto);
                assert_ne!(a, AllreduceAlg::ReduceBcast, "ordered fold at {t:?}/{bytes}");
                if t.nodes == 1 || t.max_ppn == 1 {
                    assert_ne!(a, AllreduceAlg::Hier);
                }
                let r = decide_reduce(t, bytes, &m);
                assert_ne!(r, ReduceAlg::Auto);
                assert_ne!(r, ReduceAlg::Linear, "linear reduce at {t:?}/{bytes}");
                if t.nodes == 1 || t.max_ppn == 1 {
                    assert_ne!(r, ReduceAlg::Hier);
                }
            }
        }
    }

    #[test]
    fn io_aggregator_table_boundaries() {
        // One aggregator per node on hierarchical shapes.
        assert_eq!(decide_io_aggregators(topo(8, 4, 2), 1 << 16, 4 << 20), 4);
        // Single node: one aggregator regardless of size.
        assert_eq!(decide_io_aggregators(topo(8, 1, 8), 1 << 16, 4 << 20), 1);
        // Never more aggregators than stripes.
        assert_eq!(decide_io_aggregators(topo(8, 8, 1), 1 << 16, 1 << 16), 1);
        assert_eq!(decide_io_aggregators(topo(8, 8, 1), 1 << 16, (2 << 16) + 1), 3);
        // Never more than the communicator, and ≥ 1 even for empty spans.
        assert_eq!(decide_io_aggregators(topo(2, 4, 1), 1 << 16, usize::MAX), 2);
        assert_eq!(decide_io_aggregators(topo(4, 2, 2), 1 << 16, 0), 1);
        assert_eq!(decide_io_aggregators(topo(1, 1, 1), 0, 0), 1);
    }

    #[test]
    fn chunk_plans_are_block_aligned() {
        const B: usize = crate::runtime::BLOCK;
        // Below two chunks' worth: no plan.
        assert_eq!(plan_chunks(B), None);
        assert_eq!(plan_chunks(B + 1).map(|p| p.nchunks), Some(2));
        for count in [2 * B, 3 * B + 17, 16 * B, 100 * B + 1, 1_000_000] {
            let p = plan_chunks(count).unwrap();
            assert_eq!(p.chunk_elems % B, 0, "chunk not block-aligned at {count}");
            assert!(p.chunk_elems <= 8 * B);
            assert!(p.nchunks >= 2);
            assert_eq!(p.nchunks, count.div_ceil(p.chunk_elems));
            // All chunks but the last are full; the tail is non-empty.
            assert!(count > (p.nchunks - 1) * p.chunk_elems);
        }
    }

    #[test]
    fn chunking_pays_boundary() {
        let m = omnipath();
        // A whole-block f32 chunk hides far more combine time than a few
        // rounds of latency cost.
        let block_bytes = crate::runtime::BLOCK * 4;
        assert!(chunking_pays(8 * block_bytes, 4, false, &m));
        // Tiny chunks never pay.
        assert!(!chunking_pays(64, 4, false, &m));
    }

    #[test]
    fn zero_cost_model_stays_latency_safe() {
        // With a free network every candidate ties; the tie-break must
        // stay on the first (latency-safe) candidate, deterministically.
        let m = NetworkModel::zero();
        assert_eq!(decide_allreduce(topo(8, 4, 2), 1 << 20, &m), AllreduceAlg::RecursiveDoubling);
        assert_eq!(decide_bcast(topo(8, 4, 2), 1 << 20, &m), BcastAlg::Binomial);
    }
}
