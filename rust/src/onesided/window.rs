//! RMA windows.

use crate::collective;
use crate::comm::Comm;
use crate::datatype::{pack, unpack, Datatype};
use crate::op::Op;
use crate::{mpi_err, Result};
use std::sync::{Arc, Condvar, Mutex};

/// `MPI_LOCK_EXCLUSIVE` / `MPI_LOCK_SHARED`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LockType {
    Exclusive,
    Shared,
}

/// Passive-target lock state for one target rank.
#[derive(Debug, Default)]
struct LockState {
    exclusive: bool,
    shared: usize,
}

#[derive(Debug, Default)]
struct TargetLock {
    state: Mutex<LockState>,
    cv: Condvar,
}

impl TargetLock {
    fn acquire(&self, lt: LockType) {
        let mut st = self.state.lock().unwrap();
        loop {
            match lt {
                LockType::Exclusive if !st.exclusive && st.shared == 0 => {
                    st.exclusive = true;
                    return;
                }
                LockType::Shared if !st.exclusive => {
                    st.shared += 1;
                    return;
                }
                _ => st = self.cv.wait(st).unwrap(),
            }
        }
    }

    fn release(&self, lt: LockType) {
        let mut st = self.state.lock().unwrap();
        match lt {
            LockType::Exclusive => st.exclusive = false,
            LockType::Shared => st.shared = st.shared.saturating_sub(1),
        }
        drop(st);
        self.cv.notify_all();
    }
}

/// Shared (cross-rank) part of a window.
#[derive(Debug)]
struct WinShared {
    segments: Vec<Mutex<Vec<u8>>>,
    locks: Vec<TargetLock>,
    disp_units: Vec<usize>,
}

/// An RMA window (`MPI_Win`), created collectively. Dropping it frees the
/// local view; the shared memory lives until the last rank drops.
pub struct Window {
    comm: Comm,
    key: u64,
    shared: Arc<WinShared>,
    /// Locks this rank currently holds (target → type), so unlock_all and
    /// error checking work.
    held: std::cell::RefCell<Vec<(usize, LockType)>>,
}

impl Window {
    /// `MPI_Win_allocate`: every rank contributes `local_size` bytes with
    /// displacement unit `disp_unit`. Collective over `comm` (which is
    /// duplicated internally, like real implementations do, so window
    /// traffic cannot interfere with user communication).
    pub fn allocate(comm: &Comm, local_size: usize, disp_unit: usize) -> Result<Window> {
        let comm = comm.dup()?;
        let p = comm.size();
        // Share sizes/disp units.
        let u64t = Datatype::primitive(crate::datatype::Primitive::U64);
        let mine = [(local_size as u64).to_le_bytes(), (disp_unit as u64).to_le_bytes()].concat();
        let mut all = vec![0u8; 16 * p];
        collective::allgather(&comm, Some(&mine), 2, &u64t, &mut all, 2, &u64t)?;
        let sizes: Vec<usize> =
            (0..p).map(|i| u64::from_le_bytes(all[16 * i..16 * i + 8].try_into().unwrap()) as usize).collect();
        let disp_units: Vec<usize> = (0..p)
            .map(|i| u64::from_le_bytes(all[16 * i + 8..16 * i + 16].try_into().unwrap()) as usize)
            .collect();

        // Rank 0 builds the shared segments and publishes them in the
        // fabric registry under the (unique) window-communicator context
        // id; a barrier orders publish before fetch.
        let fabric = comm.rank_ctx().fabric.clone();
        let key = 0x5749_0000_0000_0000u64 | comm.ctx_coll() as u64;
        if comm.rank() == 0 {
            let s: Arc<WinShared> = Arc::new(WinShared {
                segments: sizes.iter().map(|&n| Mutex::new(vec![0u8; n])).collect(),
                locks: (0..p).map(|_| TargetLock::default()).collect(),
                disp_units,
            });
            fabric.publish(key, s);
        }
        collective::barrier(&comm)?;
        let shared = fabric
            .fetch(key)
            .ok_or_else(|| mpi_err!(Win, "window registry entry missing"))?
            .downcast::<WinShared>()
            .map_err(|_| mpi_err!(Intern, "window registry type mismatch"))?;
        Ok(Window { comm, key, shared, held: std::cell::RefCell::new(Vec::new()) })
    }

    pub fn comm(&self) -> &Comm {
        &self.comm
    }

    pub fn size_of(&self, rank: usize) -> usize {
        self.shared.segments[rank].lock().unwrap().len()
    }

    /// Read/modify this rank's local window memory
    /// (`MPI_Win_allocate` base-pointer access).
    pub fn with_local<T>(&self, f: impl FnOnce(&mut [u8]) -> T) -> T {
        let mut seg = self.shared.segments[self.comm.rank()].lock().unwrap();
        f(&mut seg)
    }

    fn charge(&self, bytes: usize, target: usize) {
        let ctx = self.comm.rank_ctx();
        let me = ctx.world_rank;
        let tw = self.comm.group().world_rank(target).unwrap_or(me);
        let same = ctx.fabric.nodemap.same_node(me, tw);
        ctx.clock.charge(ctx.fabric.model.cost_ns(bytes, same));
    }

    fn byte_offset(&self, target: usize, disp: usize) -> usize {
        disp * self.shared.disp_units[target]
    }

    /// `MPI_Put`.
    pub fn put(&self, origin: &[u8], count: usize, dtype: &Datatype, target: usize, target_disp: usize) -> Result<()> {
        dtype.require_committed()?;
        let mut wire = Vec::new();
        pack(dtype.map(), origin, count, &mut wire)?;
        let off = self.byte_offset(target, target_disp);
        {
            let mut seg = self.shared.segments[target].lock().unwrap();
            if off + wire.len() > seg.len() {
                return Err(mpi_err!(RmaRange, "put of {} bytes at {off} exceeds window {}", wire.len(), seg.len()));
            }
            seg[off..off + wire.len()].copy_from_slice(&wire);
        }
        self.charge(wire.len(), target);
        Ok(())
    }

    /// `MPI_Get`.
    pub fn get(&self, origin: &mut [u8], count: usize, dtype: &Datatype, target: usize, target_disp: usize) -> Result<()> {
        dtype.require_committed()?;
        let nbytes = dtype.size() * count;
        let off = self.byte_offset(target, target_disp);
        let wire = {
            let seg = self.shared.segments[target].lock().unwrap();
            if off + nbytes > seg.len() {
                return Err(mpi_err!(RmaRange, "get of {nbytes} bytes at {off} exceeds window {}", seg.len()));
            }
            seg[off..off + nbytes].to_vec()
        };
        unpack(dtype.map(), &wire, origin, count)?;
        self.charge(nbytes, target);
        Ok(())
    }

    /// `MPI_Accumulate` (predefined ops + REPLACE).
    #[allow(clippy::too_many_arguments)]
    pub fn accumulate(
        &self,
        origin: &[u8],
        count: usize,
        dtype: &Datatype,
        target: usize,
        target_disp: usize,
        op: &Op,
    ) -> Result<()> {
        dtype.require_committed()?;
        let mut wire = Vec::new();
        pack(dtype.map(), origin, count, &mut wire)?;
        let off = self.byte_offset(target, target_disp);
        {
            let mut seg = self.shared.segments[target].lock().unwrap();
            if off + wire.len() > seg.len() {
                return Err(mpi_err!(RmaRange, "accumulate exceeds window"));
            }
            op.apply(dtype.map(), &wire, &mut seg[off..off + wire.len()], count)?;
        }
        self.charge(wire.len(), target);
        Ok(())
    }

    /// `MPI_Get_accumulate`: fetch old value, then combine.
    #[allow(clippy::too_many_arguments)]
    pub fn get_accumulate(
        &self,
        origin: &[u8],
        result: &mut [u8],
        count: usize,
        dtype: &Datatype,
        target: usize,
        target_disp: usize,
        op: &Op,
    ) -> Result<()> {
        dtype.require_committed()?;
        let mut wire = Vec::new();
        pack(dtype.map(), origin, count, &mut wire)?;
        let off = self.byte_offset(target, target_disp);
        let old = {
            let mut seg = self.shared.segments[target].lock().unwrap();
            if off + wire.len() > seg.len() {
                return Err(mpi_err!(RmaRange, "get_accumulate exceeds window"));
            }
            let old = seg[off..off + wire.len()].to_vec();
            op.apply(dtype.map(), &wire, &mut seg[off..off + wire.len()], count)?;
            old
        };
        unpack(dtype.map(), &old, result, count)?;
        self.charge(2 * wire.len(), target);
        Ok(())
    }

    /// `MPI_Fetch_and_op` (single element).
    pub fn fetch_and_op(
        &self,
        origin: &[u8],
        result: &mut [u8],
        dtype: &Datatype,
        target: usize,
        target_disp: usize,
        op: &Op,
    ) -> Result<()> {
        self.get_accumulate(origin, result, 1, dtype, target, target_disp, op)
    }

    /// `MPI_Compare_and_swap` (single element): writes `origin` iff the
    /// target equals `compare`; always returns the old value in `result`.
    pub fn compare_and_swap(
        &self,
        origin: &[u8],
        compare: &[u8],
        result: &mut [u8],
        dtype: &Datatype,
        target: usize,
        target_disp: usize,
    ) -> Result<()> {
        dtype.require_committed()?;
        let n = dtype.size();
        let off = self.byte_offset(target, target_disp);
        let mut owire = Vec::new();
        pack(dtype.map(), origin, 1, &mut owire)?;
        let mut cwire = Vec::new();
        pack(dtype.map(), compare, 1, &mut cwire)?;
        let old = {
            let mut seg = self.shared.segments[target].lock().unwrap();
            if off + n > seg.len() {
                return Err(mpi_err!(RmaRange, "compare_and_swap exceeds window"));
            }
            let old = seg[off..off + n].to_vec();
            if old == cwire {
                seg[off..off + n].copy_from_slice(&owire);
            }
            old
        };
        unpack(dtype.map(), &old, result, 1)?;
        self.charge(2 * n, target);
        Ok(())
    }

    // ---- synchronization ----

    /// `MPI_Win_fence`: separates RMA epochs; collective.
    pub fn fence(&self) -> Result<()> {
        collective::barrier(&self.comm)
    }

    /// `MPI_Win_lock`.
    pub fn lock(&self, lt: LockType, target: usize) -> Result<()> {
        if self.held.borrow().iter().any(|&(t, _)| t == target) {
            return Err(mpi_err!(RmaSync, "window already locked for target {target}"));
        }
        self.shared.locks[target].acquire(lt);
        self.held.borrow_mut().push((target, lt));
        Ok(())
    }

    /// `MPI_Win_unlock`.
    pub fn unlock(&self, target: usize) -> Result<()> {
        let mut held = self.held.borrow_mut();
        let idx = held
            .iter()
            .position(|&(t, _)| t == target)
            .ok_or_else(|| mpi_err!(RmaSync, "unlock of target {target} not locked"))?;
        let (_, lt) = held.remove(idx);
        self.shared.locks[target].release(lt);
        Ok(())
    }

    /// `MPI_Win_lock_all` (shared on every target).
    pub fn lock_all(&self) -> Result<()> {
        for t in 0..self.comm.size() {
            self.lock(LockType::Shared, t)?;
        }
        Ok(())
    }

    /// `MPI_Win_unlock_all`.
    pub fn unlock_all(&self) -> Result<()> {
        let held: Vec<(usize, LockType)> = self.held.borrow_mut().drain(..).collect();
        for (t, lt) in held {
            self.shared.locks[t].release(lt);
        }
        Ok(())
    }

    /// `MPI_Win_flush`: RMA here is synchronous, so flush only charges the
    /// bookkeeping (ordering is already guaranteed).
    pub fn flush(&self, _target: usize) -> Result<()> {
        Ok(())
    }

    /// Post-start-complete-wait (PSCW) active-target sync, expressed over
    /// p2p: `post` tells each origin it may access; `start` waits for the
    /// posts; `complete` notifies targets; `wait` collects completions.
    pub fn post(&self, origins: &[usize]) -> Result<()> {
        let byte = Datatype::primitive(crate::datatype::Primitive::Byte);
        for &o in origins {
            self.comm.send(&[], 0, &byte, o as i32, PSCW_POST_TAG)?;
        }
        Ok(())
    }

    pub fn start(&self, targets: &[usize]) -> Result<()> {
        let byte = Datatype::primitive(crate::datatype::Primitive::Byte);
        for &t in targets {
            let mut empty = [];
            self.comm.recv(&mut empty, 0, &byte, t as i32, PSCW_POST_TAG)?;
        }
        Ok(())
    }

    pub fn complete(&self, targets: &[usize]) -> Result<()> {
        let byte = Datatype::primitive(crate::datatype::Primitive::Byte);
        for &t in targets {
            self.comm.send(&[], 0, &byte, t as i32, PSCW_COMPLETE_TAG)?;
        }
        Ok(())
    }

    pub fn wait(&self, origins: &[usize]) -> Result<()> {
        let byte = Datatype::primitive(crate::datatype::Primitive::Byte);
        for &o in origins {
            let mut empty = [];
            self.comm.recv(&mut empty, 0, &byte, o as i32, PSCW_COMPLETE_TAG)?;
        }
        Ok(())
    }

    /// `MPI_Win_free` is collective; the registry entry is retired once
    /// every rank has arrived.
    pub fn free(self) -> Result<()> {
        collective::barrier(&self.comm)?;
        if self.comm.rank() == 0 {
            self.comm.rank_ctx().fabric.unpublish(self.key);
        }
        Ok(())
    }
}

const PSCW_POST_TAG: i32 = crate::comm::TAG_UB - 1;
const PSCW_COMPLETE_TAG: i32 = crate::comm::TAG_UB - 2;
