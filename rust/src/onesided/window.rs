//! RMA windows (`MPI_Win`) over the transport path.
//!
//! # Architecture
//!
//! A window is **rank-local exposed memory plus a shared lock table**:
//!
//! * Each rank owns the segment it contributed to `MPI_Win_allocate`,
//!   registered with its [`RankCtx`] under the window id
//!   ([`engine::register_window`]). Only the owning rank's engine thread
//!   ever touches it — remote puts/gets/accumulates arrive as `Rma*`
//!   packets through the fabric and are applied when the target's
//!   progress loop runs (the passive-target progress rule of a
//!   software-emulated RDMA stack). That single-writer discipline is what
//!   makes accumulate / fetch-and-op / compare-and-swap atomic across
//!   origins with no data locking at all.
//! * The passive-target lock table ([`LockType`] state per target) is the
//!   one genuinely shared piece, published through the fabric registry —
//!   the moral equivalent of NIC-side atomics. Acquisition is
//!   *progress-driven*: a rank polling for a contended lock keeps turning
//!   its engine ([`engine::wait_for`]), so it continues to serve inbound
//!   RMA traffic while it waits and lock cycles cannot deadlock the
//!   fabric.
//!
//! # Request-based operations and completion
//!
//! Every data op (`rput`/`rget`/`raccumulate`/`rget_accumulate`/
//! `rcompare_and_swap`) is asynchronous at the substrate: it packs the
//! origin payload onto a pooled wire buffer (contiguous layouts are a
//! single DMA-modeled append — zero CPU copies, nothing charged to
//! `wire_bytes_copied`; non-contiguous staging is charged), injects one
//! `Rma*` packet, and returns an [`RmaOp`] whose token completes when the
//! target's ack/response arrives. Because the origin names the target
//! address outright, there is no rendezvous handshake — a put is one data
//! crossing plus an ack regardless of size.
//!
//! The blocking API (`put`/`get`/...) is the async API plus an immediate
//! wait. The modern layer wraps [`RmaOp`] into an
//! [`MpiFuture`](crate::modern::MpiFuture) via [`RmaOp::request`], so RMA
//! chains compose with `.then()`/`when_all` like any other request.
//!
//! # Epoch invariants (what each sync call guarantees)
//!
//! * [`Window::flush`]/[`Window::flush_all`] — every op this rank issued
//!   on the window is complete at its target (ack received) on return.
//! * [`Window::fence`] — flush_all **then** barrier: all ops of the
//!   closing epoch, by every rank, are applied before any rank exits.
//! * [`Window::unlock`]/[`Window::unlock_all`] — flush first, then
//!   release, so a lock epoch's ops are remotely complete before the lock
//!   is observable as free.
//! * PSCW (`post`/`start`/`complete`/`wait`) — `complete` is preceded by
//!   a flush; per-sender FIFO delivery then orders the access epoch's
//!   last data packet before the completion message at the target.
//!
//! ```
//! use ferrompi::datatype::{Datatype, Primitive};
//! use ferrompi::onesided::Window;
//! use ferrompi::universe::Universe;
//!
//! let firsts = Universe::test(2).run(|world| {
//!     let i64t = Datatype::primitive(Primitive::I64);
//!     let win = Window::allocate(world, 8, 8).unwrap();
//!     win.fence().unwrap();
//!     // Each rank writes (rank+1) into its peer's single slot — as a
//!     // started op whose completion is awaited explicitly.
//!     let peer = 1 - world.rank();
//!     let val = (world.rank() as i64 + 1).to_le_bytes();
//!     let op = win.rput(&val, 1, &i64t, peer, 0).unwrap();
//!     op.wait().unwrap();
//!     win.fence().unwrap();
//!     let got = win.with_local(|m| i64::from_le_bytes(m[..8].try_into().unwrap()));
//!     win.free().unwrap();
//!     got
//! });
//! assert_eq!(firsts, vec![2, 1]);
//! ```

use crate::collective;
use crate::comm::Comm;
use crate::datatype::{pack_size, unpack, Datatype};
use crate::op::Op;
use crate::p2p::engine::{self, RmaKind};
use crate::p2p::RankCtx;
use crate::request::{CustomRequest, Request};
use crate::transport::{BufferPool, PoolHandle, WireBytes};
use crate::{mpi_err, Result};
use std::cell::{Cell, RefCell};
use std::rc::Rc;
use std::sync::{Arc, Mutex};

/// `MPI_LOCK_EXCLUSIVE` / `MPI_LOCK_SHARED`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LockType {
    Exclusive,
    Shared,
}

/// Passive-target lock state for one target rank.
#[derive(Debug, Default)]
struct LockState {
    exclusive: bool,
    shared: usize,
}

/// One target's lock word — shared across rank threads like an RDMA
/// atomic. Deliberately condvar-free: contended acquirers poll through
/// [`engine::wait_for`] so their progress engine keeps serving inbound
/// RMA packets while they wait (a condvar sleep here deadlocks the
/// fabric: the holder may be waiting for *this* rank to ack a put).
#[derive(Debug, Default)]
struct TargetLock {
    state: Mutex<LockState>,
}

impl TargetLock {
    /// Try to take the lock; never blocks.
    fn try_acquire(&self, lt: LockType) -> bool {
        let mut st = self.state.lock().unwrap();
        match lt {
            LockType::Exclusive if !st.exclusive && st.shared == 0 => {
                st.exclusive = true;
                true
            }
            LockType::Shared if !st.exclusive => {
                st.shared += 1;
                true
            }
            _ => false,
        }
    }

    fn release(&self, lt: LockType) {
        let mut st = self.state.lock().unwrap();
        match lt {
            LockType::Exclusive => st.exclusive = false,
            LockType::Shared => st.shared = st.shared.saturating_sub(1),
        }
    }
}

/// The registry-published (cross-rank) part of a window: only the lock
/// table — window *data* is rank-local (see module docs).
#[derive(Debug)]
struct WinMeta {
    locks: Vec<TargetLock>,
}

/// Origin-side completion handle of one one-sided operation. Implements
/// [`CustomRequest`] so an RMA op *is* an `MPI_Request` to the completion
/// family (`wait_all`, `when_all`, `MpiFuture`). The handle also keeps the
/// window's outstanding-op list honest: consuming the completion (or
/// dropping the last reference) deregisters the token.
#[derive(Debug)]
struct RmaOpHandle {
    ctx: Rc<RankCtx>,
    token: u64,
    /// The owning window's outstanding-token list (flush waits on it).
    pending: Rc<RefCell<Vec<u64>>>,
    /// Response payload, stashed by `take_status` for the extractor.
    payload: RefCell<Option<WireBytes>>,
    taken: Cell<bool>,
}

impl RmaOpHandle {
    fn deregister(&self) {
        self.pending.borrow_mut().retain(|&t| t != self.token);
    }
}

impl CustomRequest for RmaOpHandle {
    fn done(&self) -> bool {
        engine::rma_done(&self.ctx, self.token)
    }

    fn take_status(&self) -> Result<crate::p2p::Status> {
        let data = engine::take_rma_result(&self.ctx, self.token)?;
        self.deregister();
        let bytes = data.len();
        *self.payload.borrow_mut() = Some(data);
        self.taken.set(true);
        Ok(crate::p2p::Status { source: -1, tag: -1, bytes, cancelled: false })
    }
}

impl Drop for RmaOpHandle {
    /// Dropping an unconsumed op (e.g. an abandoned future) blocks until
    /// the target's reply arrives, then discards it: the response may pin
    /// a pooled wire buffer that must go back to the pool, and a token
    /// left pending would trip the quiescence audit. Skipped while
    /// unwinding (the engine only runs on this dying thread anyway).
    fn drop(&mut self) {
        if self.taken.get() {
            return;
        }
        self.deregister();
        if std::thread::panicking() {
            return;
        }
        if engine::wait_for(&self.ctx, || engine::rma_done(&self.ctx, self.token)).is_ok() {
            let _ = engine::take_rma_result(&self.ctx, self.token);
        }
    }
}

/// A started one-sided operation (the product of
/// [`Window::rput`]-family calls): a completion token plus, for
/// get-class ops, the response bytes.
#[derive(Debug)]
pub struct RmaOp {
    handle: Rc<RmaOpHandle>,
}

impl RmaOp {
    /// View this op as an `MPI_Request` for the completion family. Create
    /// **one** request per op — the request consumes the completion, so a
    /// second one would find the token already taken.
    pub fn request(&self) -> Request {
        Request::custom(self.handle.ctx.clone(), self.handle.clone())
    }

    /// Drive to completion, discarding any response payload (put/acc).
    pub fn wait(self) -> Result<()> {
        self.request().wait().map(|_| ())
    }

    /// Drive to completion and take the target's response bytes (get /
    /// fetching-accumulate / compare-and-swap; empty for put/acc).
    pub fn wait_bytes(self) -> Result<WireBytes> {
        self.request().wait()?;
        Ok(self.take_payload())
    }

    /// The stashed response after completion (empty if none). Used by the
    /// modern layer's future extractors; meaningless before the request
    /// produced by [`RmaOp::request`] has completed.
    pub fn take_payload(&self) -> WireBytes {
        self.handle.payload.borrow_mut().take().unwrap_or_else(WireBytes::empty)
    }
}

/// An RMA window (`MPI_Win`), created collectively over a communicator
/// (which is duplicated internally, like real implementations do, so
/// window synchronization cannot interfere with user communication).
///
/// See the [module docs](self) for the architecture and the epoch
/// invariants every synchronization method upholds.
pub struct Window {
    comm: Comm,
    /// Fabric-registry key of the shared lock table.
    key: u64,
    /// Fabric-wide window id (the dup'd communicator's collective context
    /// id — unique per job), carried in every `Rma*` packet.
    win_id: u32,
    meta: Arc<WinMeta>,
    /// Per-rank segment sizes in bytes (allgathered at creation; origin-
    /// side range checks consult this so misuse fails fast and locally).
    sizes: Vec<usize>,
    disp_units: Vec<usize>,
    /// Locks this rank currently holds (target → type), so unlock_all and
    /// error checking work.
    held: RefCell<Vec<(usize, LockType)>>,
    /// Tokens of this rank's outstanding ops on this window; flush and
    /// epoch closes wait on them.
    pending: Rc<RefCell<Vec<u64>>>,
}

impl Window {
    /// `MPI_Win_allocate`: every rank contributes `local_size` bytes with
    /// displacement unit `disp_unit`. Collective over `comm`.
    pub fn allocate(comm: &Comm, local_size: usize, disp_unit: usize) -> Result<Window> {
        let comm = comm.dup()?;
        let p = comm.size();
        // Share sizes/disp units.
        let u64t = Datatype::primitive(crate::datatype::Primitive::U64);
        let mine = [(local_size as u64).to_le_bytes(), (disp_unit as u64).to_le_bytes()].concat();
        let mut all = vec![0u8; 16 * p];
        collective::allgather(&comm, Some(&mine), 2, &u64t, &mut all, 2, &u64t)?;
        let sizes: Vec<usize> = (0..p)
            .map(|i| u64::from_le_bytes(all[16 * i..16 * i + 8].try_into().unwrap()) as usize)
            .collect();
        let disp_units: Vec<usize> = (0..p)
            .map(|i| u64::from_le_bytes(all[16 * i + 8..16 * i + 16].try_into().unwrap()) as usize)
            .collect();

        // Expose this rank's own segment to the engine, publish the shared
        // lock table under the (unique) window-communicator context id; a
        // barrier orders publish before fetch and registration before any
        // peer's first RMA packet.
        let win_id = comm.ctx_coll();
        let ctx = comm.rank_ctx().clone();
        engine::register_window(&ctx, win_id, sizes[comm.rank()]);
        let fabric = ctx.fabric.clone();
        let key = 0x5749_0000_0000_0000u64 | win_id as u64;
        let meta = if fabric.is_multiprocess() {
            // The object registry is per-process; a launched job cannot
            // share the lock table. Active-target sync (fence/PSCW) still
            // works — the barrier below keeps registration ordered before
            // any peer's first RMA packet — but passive-target locks are
            // refused in `lock()`.
            let m: Arc<WinMeta> =
                Arc::new(WinMeta { locks: (0..p).map(|_| TargetLock::default()).collect() });
            collective::barrier(&comm)?;
            m
        } else {
            if comm.rank() == 0 {
                let m: Arc<WinMeta> =
                    Arc::new(WinMeta { locks: (0..p).map(|_| TargetLock::default()).collect() });
                fabric.publish(key, m);
            }
            collective::barrier(&comm)?;
            fabric
                .fetch(key)
                .ok_or_else(|| mpi_err!(Win, "window registry entry missing"))?
                .downcast::<WinMeta>()
                .map_err(|_| mpi_err!(Intern, "window registry type mismatch"))?
        };
        Ok(Window {
            comm,
            key,
            win_id,
            meta,
            sizes,
            disp_units,
            held: RefCell::new(Vec::new()),
            pending: Rc::new(RefCell::new(Vec::new())),
        })
    }

    pub fn comm(&self) -> &Comm {
        &self.comm
    }

    /// Segment size (bytes) rank `rank` exposed.
    pub fn size_of(&self, rank: usize) -> usize {
        self.sizes[rank]
    }

    /// Read/modify this rank's local window memory
    /// (`MPI_Win_allocate` base-pointer access).
    ///
    /// Invariant: the closure must not make MPI calls — driving the
    /// progress engine inside it could deliver a remote RMA op to this
    /// same segment while it is mutably borrowed. Remote ops queued in the
    /// mailbox are applied only by this rank's later progress calls, so
    /// plain local access here is race-free by construction.
    pub fn with_local<T>(&self, f: impl FnOnce(&mut [u8]) -> T) -> T {
        let mem = engine::window_local(self.comm.rank_ctx(), self.win_id)
            .expect("window registered for its lifetime");
        let mut seg = mem.seg.borrow_mut();
        f(&mut seg)
    }

    fn byte_span(&self, target: usize, disp: usize, nbytes: usize) -> Result<usize> {
        if target >= self.comm.size() {
            return Err(mpi_err!(Rank, "RMA target rank {target} out of range"));
        }
        let off = disp
            .checked_mul(self.disp_units[target])
            .ok_or_else(|| mpi_err!(RmaRange, "RMA displacement {disp} overflows"))?;
        match off.checked_add(nbytes) {
            Some(end) if end <= self.sizes[target] => Ok(off),
            _ => Err(mpi_err!(
                RmaRange,
                "RMA span of {nbytes} bytes at {off} exceeds segment of {} on rank {target}",
                self.sizes[target]
            )),
        }
    }

    /// Inject one op and track its token on this window.
    fn start_op(&self, target: usize, off: usize, kind: RmaKind) -> Result<RmaOp> {
        let ctx = self.comm.rank_ctx().clone();
        let dst_world = self.comm.group().world_rank(target)?;
        let token = engine::start_rma(&ctx, dst_world, self.win_id, off, kind);
        self.pending.borrow_mut().push(token);
        Ok(RmaOp {
            handle: Rc::new(RmaOpHandle {
                ctx,
                token,
                pending: self.pending.clone(),
                payload: RefCell::new(None),
                taken: Cell::new(false),
            }),
        })
    }

    fn predefined(op: &Op) -> Result<crate::op::OpKind> {
        match op {
            Op::Predefined(k) => Ok(*k),
            Op::User { .. } => {
                Err(mpi_err!(Op, "RMA accumulate requires a predefined op (MPI-4.0 §12.3.4)"))
            }
        }
    }

    // ---- request-based (asynchronous) operations ----

    /// `MPI_Rput`: started put. The origin buffer is packed onto a pooled
    /// wire buffer before return (contiguous = one DMA-modeled append,
    /// zero charged copies), so it is immediately reusable.
    pub fn rput(
        &self,
        origin: &[u8],
        count: usize,
        dtype: &Datatype,
        target: usize,
        target_disp: usize,
    ) -> Result<RmaOp> {
        dtype.require_committed()?;
        let nbytes = pack_size(dtype.map(), count);
        let off = self.byte_span(target, target_disp, nbytes)?;
        let data = engine::pack_wire(self.comm.rank_ctx(), dtype.map(), origin, count)?;
        self.start_op(target, off, RmaKind::Put { data })
    }

    /// `MPI_Rget`: started get. The response bytes arrive on a pooled wire
    /// buffer; take them with [`RmaOp::wait_bytes`] (or let the modern
    /// layer's future unpack them).
    pub fn rget(
        &self,
        count: usize,
        dtype: &Datatype,
        target: usize,
        target_disp: usize,
    ) -> Result<RmaOp> {
        dtype.require_committed()?;
        let nbytes = pack_size(dtype.map(), count);
        let off = self.byte_span(target, target_disp, nbytes)?;
        self.start_op(target, off, RmaKind::Get { nbytes })
    }

    /// `MPI_Raccumulate` (predefined ops + REPLACE), atomic at the target.
    #[allow(clippy::too_many_arguments)]
    pub fn raccumulate(
        &self,
        origin: &[u8],
        count: usize,
        dtype: &Datatype,
        target: usize,
        target_disp: usize,
        op: &Op,
    ) -> Result<RmaOp> {
        dtype.require_committed()?;
        let kind = Self::predefined(op)?;
        let nbytes = pack_size(dtype.map(), count);
        let off = self.byte_span(target, target_disp, nbytes)?;
        let data = engine::pack_wire(self.comm.rank_ctx(), dtype.map(), origin, count)?;
        self.start_op(
            target,
            off,
            RmaKind::Acc { data, count, map: dtype.shared_map(), op: kind, fetch: false },
        )
    }

    /// `MPI_Rget_accumulate`: atomically fetch the old bytes, then
    /// combine. The response carries the pre-op value.
    #[allow(clippy::too_many_arguments)]
    pub fn rget_accumulate(
        &self,
        origin: &[u8],
        count: usize,
        dtype: &Datatype,
        target: usize,
        target_disp: usize,
        op: &Op,
    ) -> Result<RmaOp> {
        dtype.require_committed()?;
        let kind = Self::predefined(op)?;
        let nbytes = pack_size(dtype.map(), count);
        let off = self.byte_span(target, target_disp, nbytes)?;
        let data = engine::pack_wire(self.comm.rank_ctx(), dtype.map(), origin, count)?;
        self.start_op(
            target,
            off,
            RmaKind::Acc { data, count, map: dtype.shared_map(), op: kind, fetch: true },
        )
    }

    /// Started single-element compare-and-swap; the response carries the
    /// old target bytes.
    pub fn rcompare_and_swap(
        &self,
        origin: &[u8],
        compare: &[u8],
        dtype: &Datatype,
        target: usize,
        target_disp: usize,
    ) -> Result<RmaOp> {
        dtype.require_committed()?;
        let n = dtype.size();
        let off = self.byte_span(target, target_disp, n)?;
        let ctx = self.comm.rank_ctx();
        // origin ‖ compare on one pooled buffer.
        let mut wire = ctx.fabric.pool.take(2 * n);
        crate::datatype::pack(dtype.map(), origin, 1, &mut wire)?;
        crate::datatype::pack(dtype.map(), compare, 1, &mut wire)?;
        if !dtype.map().is_contiguous() {
            ctx.fabric.pool.count_copied(wire.len());
        }
        self.start_op(target, off, RmaKind::Cas { data: wire.freeze() })
    }

    // ---- blocking operations (async + immediate wait) ----

    /// Unpack a get-class response into the caller's typed buffer (see
    /// [`unpack_charged`] — the one copy-accounting rule for responses).
    fn unpack_response(
        &self,
        data: &WireBytes,
        buf: &mut [u8],
        count: usize,
        dtype: &Datatype,
    ) -> Result<()> {
        unpack_charged(&self.comm.rank_ctx().fabric.pool, dtype, data, buf, count)
    }

    /// `MPI_Put` (blocking until remotely complete).
    pub fn put(
        &self,
        origin: &[u8],
        count: usize,
        dtype: &Datatype,
        target: usize,
        target_disp: usize,
    ) -> Result<()> {
        self.rput(origin, count, dtype, target, target_disp)?.wait()
    }

    /// `MPI_Get`.
    pub fn get(
        &self,
        origin: &mut [u8],
        count: usize,
        dtype: &Datatype,
        target: usize,
        target_disp: usize,
    ) -> Result<()> {
        let data = self.rget(count, dtype, target, target_disp)?.wait_bytes()?;
        self.unpack_response(&data, origin, count, dtype)
    }

    /// `MPI_Accumulate` (predefined ops + REPLACE).
    #[allow(clippy::too_many_arguments)]
    pub fn accumulate(
        &self,
        origin: &[u8],
        count: usize,
        dtype: &Datatype,
        target: usize,
        target_disp: usize,
        op: &Op,
    ) -> Result<()> {
        self.raccumulate(origin, count, dtype, target, target_disp, op)?.wait()
    }

    /// `MPI_Get_accumulate`: fetch old value, then combine — one atomic
    /// step at the target.
    #[allow(clippy::too_many_arguments)]
    pub fn get_accumulate(
        &self,
        origin: &[u8],
        result: &mut [u8],
        count: usize,
        dtype: &Datatype,
        target: usize,
        target_disp: usize,
        op: &Op,
    ) -> Result<()> {
        let data =
            self.rget_accumulate(origin, count, dtype, target, target_disp, op)?.wait_bytes()?;
        self.unpack_response(&data, result, count, dtype)
    }

    /// `MPI_Fetch_and_op` (single element).
    pub fn fetch_and_op(
        &self,
        origin: &[u8],
        result: &mut [u8],
        dtype: &Datatype,
        target: usize,
        target_disp: usize,
        op: &Op,
    ) -> Result<()> {
        self.get_accumulate(origin, result, 1, dtype, target, target_disp, op)
    }

    /// `MPI_Compare_and_swap` (single element): writes `origin` iff the
    /// target equals `compare`; always returns the old value in `result`.
    pub fn compare_and_swap(
        &self,
        origin: &[u8],
        compare: &[u8],
        result: &mut [u8],
        dtype: &Datatype,
        target: usize,
        target_disp: usize,
    ) -> Result<()> {
        let data =
            self.rcompare_and_swap(origin, compare, dtype, target, target_disp)?.wait_bytes()?;
        self.unpack_response(&data, result, 1, dtype)
    }

    // ---- synchronization ----

    /// `MPI_Win_flush`: every op this rank issued on the window is
    /// complete at its target on return. (Implemented as a full
    /// [`Window::flush_all`] — per-target would be legal but weaker.)
    pub fn flush(&self, _target: usize) -> Result<()> {
        self.flush_all()
    }

    /// `MPI_Win_flush_all`: wait (driving progress) until the target ack
    /// of every outstanding op has arrived. Completion state is left for
    /// the ops' futures — a flushed future resolves without blocking.
    pub fn flush_all(&self) -> Result<()> {
        let toks: Vec<u64> = self.pending.borrow().clone();
        if toks.is_empty() {
            return Ok(());
        }
        let ctx = self.comm.rank_ctx();
        engine::wait_for(ctx, || toks.iter().all(|&t| engine::rma_done(ctx, t)))
    }

    /// `MPI_Win_fence`: closes one epoch and opens the next. Flushes this
    /// rank's outstanding ops, then barriers — after the fence every op
    /// of the closing epoch, by every rank, is applied at its target.
    pub fn fence(&self) -> Result<()> {
        self.flush_all()?;
        collective::barrier(&self.comm)
    }

    /// `MPI_Win_lock`. Contended acquisition keeps driving the progress
    /// engine, so inbound RMA traffic is served while waiting.
    pub fn lock(&self, lt: LockType, target: usize) -> Result<()> {
        if self.comm.rank_ctx().fabric.is_multiprocess() {
            return Err(mpi_err!(
                RmaSync,
                "passive-target locks need a shared lock table and are unavailable on \
                 multi-process backends — use fence or post/start/complete/wait"
            ));
        }
        if self.held.borrow().iter().any(|&(t, _)| t == target) {
            return Err(mpi_err!(RmaSync, "window already locked for target {target}"));
        }
        let lock = &self.meta.locks[target];
        engine::wait_for(self.comm.rank_ctx(), || lock.try_acquire(lt))?;
        self.held.borrow_mut().push((target, lt));
        Ok(())
    }

    /// `MPI_Win_unlock`: flushes the epoch's ops, then releases — the
    /// lock is never observable as free before its ops are remotely
    /// complete.
    pub fn unlock(&self, target: usize) -> Result<()> {
        let idx = self
            .held
            .borrow()
            .iter()
            .position(|&(t, _)| t == target)
            .ok_or_else(|| mpi_err!(RmaSync, "unlock of target {target} not locked"))?;
        self.flush_all()?;
        let (_, lt) = self.held.borrow_mut().remove(idx);
        self.meta.locks[target].release(lt);
        Ok(())
    }

    /// `MPI_Win_lock_all` (shared on every target).
    pub fn lock_all(&self) -> Result<()> {
        for t in 0..self.comm.size() {
            self.lock(LockType::Shared, t)?;
        }
        Ok(())
    }

    /// `MPI_Win_unlock_all` (flushes first, like [`Window::unlock`]).
    pub fn unlock_all(&self) -> Result<()> {
        self.flush_all()?;
        let held: Vec<(usize, LockType)> = self.held.borrow_mut().drain(..).collect();
        for (t, lt) in held {
            self.meta.locks[t].release(lt);
        }
        Ok(())
    }

    /// Post-start-complete-wait (PSCW) active-target sync, expressed over
    /// p2p: `post` tells each origin it may access; `start` waits for the
    /// posts; `complete` flushes then notifies targets (per-sender FIFO
    /// orders the epoch's last data packet before the notification);
    /// `wait` collects completions — and, by draining the mailbox to get
    /// them, applies the epoch's ops first.
    pub fn post(&self, origins: &[usize]) -> Result<()> {
        let byte = Datatype::primitive(crate::datatype::Primitive::Byte);
        for &o in origins {
            self.comm.send(&[], 0, &byte, o as i32, PSCW_POST_TAG)?;
        }
        Ok(())
    }

    pub fn start(&self, targets: &[usize]) -> Result<()> {
        let byte = Datatype::primitive(crate::datatype::Primitive::Byte);
        for &t in targets {
            let mut empty = [];
            self.comm.recv(&mut empty, 0, &byte, t as i32, PSCW_POST_TAG)?;
        }
        Ok(())
    }

    pub fn complete(&self, targets: &[usize]) -> Result<()> {
        self.flush_all()?;
        let byte = Datatype::primitive(crate::datatype::Primitive::Byte);
        for &t in targets {
            self.comm.send(&[], 0, &byte, t as i32, PSCW_COMPLETE_TAG)?;
        }
        Ok(())
    }

    pub fn wait(&self, origins: &[usize]) -> Result<()> {
        let byte = Datatype::primitive(crate::datatype::Primitive::Byte);
        for &o in origins {
            let mut empty = [];
            self.comm.recv(&mut empty, 0, &byte, o as i32, PSCW_COMPLETE_TAG)?;
        }
        Ok(())
    }

    /// `MPI_Win_free`: collective. Flushes, barriers so no rank can have
    /// traffic in flight toward the window, then retires the local
    /// segment and (on rank 0) the registry entry.
    ///
    /// Freeing while this rank still holds a passive-target lock is
    /// erroneous (`RmaSync`); the teardown still completes — locks
    /// released, segment retired — so the job stays quiescent and the
    /// error is the only residue.
    pub fn free(self) -> Result<()> {
        self.flush_all()?;
        // Release any erroneously-held locks *before* the barrier: a peer
        // spinning on one of them may be unable to reach its own free()
        // barrier until the lock frees — releasing after would deadlock.
        let held: Vec<(usize, LockType)> = self.held.borrow_mut().drain(..).collect();
        for &(t, lt) in &held {
            self.meta.locks[t].release(lt);
        }
        collective::barrier(&self.comm)?;
        engine::unregister_window(self.comm.rank_ctx(), self.win_id);
        if self.comm.rank() == 0 && !self.comm.rank_ctx().fabric.is_multiprocess() {
            self.comm.rank_ctx().fabric.unpublish(self.key);
        }
        if held.is_empty() {
            Ok(())
        } else {
            Err(mpi_err!(
                RmaSync,
                "MPI_Win_free with {} passive-target lock(s) still held",
                held.len()
            ))
        }
    }
}

/// Unpack a get-class RMA response into a typed buffer, charging the
/// copy counter for non-contiguous scatter exactly like the receive path
/// does. The single accounting rule for response unpacking — shared by
/// the blocking substrate ops and the modern layer's async extractors,
/// so the zero-copy pvar cannot diverge between the two forms of one
/// operation.
pub(crate) fn unpack_charged(
    pool: &std::sync::Arc<BufferPool>,
    dtype: &Datatype,
    bytes: &[u8],
    buf: &mut [u8],
    count: usize,
) -> Result<()> {
    let used = unpack(dtype.map(), bytes, buf, count)?;
    if !dtype.map().is_contiguous() {
        pool.count_copied(used);
    }
    Ok(())
}

const PSCW_POST_TAG: i32 = crate::comm::TAG_UB - 1;
const PSCW_COMPLETE_TAG: i32 = crate::comm::TAG_UB - 2;
