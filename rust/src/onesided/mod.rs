//! One-sided communication (MPI-4.0 §12): windows, request-based
//! put/get/accumulate, and the three synchronization families (fence;
//! post-start-complete-wait; passive-target lock/unlock).
//!
//! Simulation mapping: window memory is **rank-local** — each rank exposes
//! its segment to its own progress engine, and remote operations travel
//! the ordinary fabric as `Rma*` packets on pooled
//! [`WireBytes`](crate::transport::WireBytes) buffers (no rendezvous
//! handshake: the origin names the target address, exactly like an RDMA
//! verb). The target's engine thread applies each op and acks it, which
//! serializes RMA atomics for free and charges the α–β model through the
//! packet clock causally. Only the passive-target lock table is shared
//! across ranks (the moral equivalent of NIC-side atomics), and waiting
//! for it drives the progress engine so lock contention cannot stall
//! inbound traffic.
//!
//! Every operation is asynchronous at the substrate ([`window::RmaOp`]);
//! blocking calls are `start + wait`. See [`window`] for the epoch
//! invariants and `docs/RMA.md` for the full model.

pub mod window;

pub use window::{LockType, RmaOp, Window};
