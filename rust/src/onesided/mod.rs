//! One-sided communication (MPI-4.0 §12): windows, put/get/accumulate,
//! and the three synchronization families (fence; post-start-complete-wait;
//! passive-target lock/unlock).
//!
//! Simulation mapping: window memory is owned by the window object and
//! shared across rank threads behind per-rank mutexes — the moral
//! equivalent of RDMA-exposed memory. RMA data movement charges the α–β
//! model to the *origin's* clock (one-sided: the target's CPU is not
//! involved), and synchronization calls ride the ordinary collective /
//! p2p machinery, which propagates clocks causally.

pub mod window;

pub use window::{LockType, Window};
