//! The `ferrompi` CLI: launch simulated jobs, run the Figure 1 benchmark,
//! inspect the tool (MPI_T) interface and the AOT artifacts.

use ferrompi::coordinator::{figure1_report, run_mpibench, MpiBenchConfig};
use ferrompi::modern::Communicator;
use ferrompi::tool;
use ferrompi::universe::Universe;
use ferrompi::util::cli::{help, Args, OptSpec};
use std::process::ExitCode;

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, rest) = match argv.split_first() {
        Some((c, r)) => (c.as_str(), r.to_vec()),
        None => {
            print_usage();
            return ExitCode::FAILURE;
        }
    };
    // The launcher and its worker entry return raw exit codes (a
    // failing rank's code must pass through to the shepherd).
    match cmd {
        "launch" => {
            return ExitCode::from(ferrompi::coordinator::launch::cli_main(&rest).clamp(0, 255) as u8)
        }
        "__worker" => {
            let (name, wargs) = match rest.split_first() {
                Some((n, a)) => (n.as_str(), a.to_vec()),
                None => {
                    eprintln!("__worker needs a builtin name");
                    return ExitCode::FAILURE;
                }
            };
            return ExitCode::from(
                ferrompi::coordinator::launch::worker_main(name, &wargs).clamp(0, 255) as u8,
            );
        }
        _ => {}
    }
    let result = match cmd {
        "bench" => cmd_bench(&rest),
        "selftest" => cmd_selftest(&rest),
        "pvars" => cmd_pvars(&rest),
        "cvars" => cmd_cvars(),
        "artifacts" => cmd_artifacts(),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => Err(format!("unknown command '{other}' (try `ferrompi help`)")),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn print_usage() {
    println!(
        "ferrompi — reproduction of 'A C++20 Interface for MPI 4.0'\n\n\
         commands:\n\
         \x20 launch     bring up an mpiexec-style multi-process job (see ferrompi launch --help)\n\
         \x20 bench      run the mpiBench sweep (Figure 1)\n\
         \x20 selftest   quick end-to-end smoke across all layers\n\
         \x20 pvars      run a small job and dump MPI_T performance variables\n\
         \x20 cvars      list MPI_T control variables\n\
         \x20 artifacts  check the AOT artifact set\n\
         \x20 help       this text\n"
    );
}

fn bench_spec() -> Vec<OptSpec> {
    vec![
        OptSpec { name: "nodes", takes_value: true, default: Some("1,2,4,8,16"), help: "node counts to sweep" },
        OptSpec { name: "ppn", takes_value: true, default: Some("2"), help: "ranks per node" },
        OptSpec { name: "reps", takes_value: true, default: Some("10"), help: "repetitions per measurement" },
        OptSpec { name: "iters", takes_value: true, default: Some("10"), help: "ops per timed loop" },
        OptSpec { name: "max-pow", takes_value: true, default: Some("17"), help: "max message length exponent (2^n)" },
        OptSpec { name: "min-pow", takes_value: true, default: Some("1"), help: "min message length exponent" },
        OptSpec { name: "out", takes_value: true, default: Some("results"), help: "output directory for CSVs" },
        OptSpec { name: "quick", takes_value: false, default: None, help: "CI-sized subset" },
        OptSpec { name: "help", takes_value: false, default: None, help: "show help" },
    ]
}

fn cmd_bench(rest: &[String]) -> Result<(), String> {
    let spec = bench_spec();
    let args = Args::parse(rest, &spec)?;
    if args.flag("help") {
        println!("{}", help("ferrompi bench", "regenerate the paper's Figure 1", &spec));
        return Ok(());
    }
    let cfg = if args.flag("quick") {
        MpiBenchConfig::quick()
    } else {
        let min: u32 = args.get_parsed("min-pow")?;
        let max: u32 = args.get_parsed("max-pow")?;
        MpiBenchConfig {
            msg_lens: (min..=max).map(|n| 1usize << n).collect(),
            node_counts: args.get_list("nodes")?,
            ppn: args.get_parsed("ppn")?,
            reps: args.get_parsed("reps")?,
            iters: args.get_parsed("iters")?,
            ..MpiBenchConfig::paper()
        }
    };
    let rows = run_mpibench(&cfg, |msg| eprintln!("{msg}"));
    let report = figure1_report(&rows);
    let out = std::path::PathBuf::from(args.get("out").unwrap_or("results"));
    std::fs::create_dir_all(&out).map_err(|e| e.to_string())?;
    std::fs::write(out.join("mpibench_rows.csv"), &report.rows_csv).map_err(|e| e.to_string())?;
    std::fs::write(out.join("figure1.csv"), &report.figure1_csv).map_err(|e| e.to_string())?;
    std::fs::write(out.join("figure1.md"), &report.markdown).map_err(|e| e.to_string())?;
    println!("{}", report.markdown);
    println!("wrote {}/mpibench_rows.csv, figure1.csv, figure1.md", out.display());
    Ok(())
}

fn cmd_selftest(_rest: &[String]) -> Result<(), String> {
    print!("substrate (4 ranks, allreduce+bcast) ... ");
    let sums = Universe::test(4).run(|world| {
        let comm = Communicator::world(world);
        let s = comm.all_reduce(comm.rank() as i64 + 1, ferrompi::modern::ReduceOp::Sum).unwrap();
        let mut v = if comm.rank() == 0 { 7i32 } else { 0 };
        comm.broadcast(&mut v, 0).unwrap();
        assert_eq!(v, 7);
        s
    });
    assert!(sums.iter().all(|&s| s == 10));
    println!("ok");

    print!("AOT artifacts + PJRT execution ... ");
    if ferrompi::runtime::artifacts_available() {
        let eng = ferrompi::runtime::engine().map_err(|e| e.to_string())?;
        let x = vec![1.0f32; 100];
        let mut y = vec![2.0f32; 100];
        eng.combine_f32("sum", &x, &mut y).map_err(|e| e.to_string())?;
        assert!(y.iter().all(|&v| v == 3.0));
        println!("ok");
    } else {
        println!("skipped (run `make artifacts`)");
    }
    println!("selftest passed");
    Ok(())
}

fn cmd_pvars(_rest: &[String]) -> Result<(), String> {
    let dump = Universe::new(2, 2).run(|world| {
        let comm = Communicator::world(world);
        // Generate some traffic.
        let _ = comm.all_reduce(comm.rank() as i64, ferrompi::modern::ReduceOp::Sum).unwrap();
        for _ in 0..3 {
            comm.barrier().unwrap();
        }
        if comm.rank() == 0 {
            let session = tool::PvarSession::create(comm.native());
            Some(session.read_all())
        } else {
            None
        }
    });
    println!("{:<28} {:>12}", "pvar", "value");
    for (name, value) in dump[0].as_ref().unwrap() {
        println!("{name:<28} {value:>12}");
    }
    Ok(())
}

fn cmd_cvars() -> Result<(), String> {
    println!("{:<28} {:>8}  {}", "cvar", "writable", "value / description");
    for c in tool::cvars() {
        let v = tool::cvar_read(c.name).unwrap_or_else(|_| "?".into());
        println!("{:<28} {:>8}  {} — {}", c.name, c.writable, v, c.description);
    }
    Ok(())
}

fn cmd_artifacts() -> Result<(), String> {
    if !ferrompi::runtime::artifacts_available() {
        return Err("artifacts missing — run `make artifacts`".into());
    }
    let eng = ferrompi::runtime::engine().map_err(|e| e.to_string())?;
    eng.warmup().map_err(|e| e.to_string())?;
    println!("all artifacts load and compile OK");
    Ok(())
}
