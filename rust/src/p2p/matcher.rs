//! The matching engine: posted-receive queue and unexpected-message queue.
//!
//! MPI's non-overtaking rule — messages between the same (sender, receiver,
//! communicator, tag) match in send order — falls out of FIFO mailboxes plus
//! FIFO scanning of both queues here.

use crate::transport::WireBytes;
use std::collections::VecDeque;

/// What a receive is willing to match. `None` = wildcard
/// (`MPI_ANY_SOURCE` / `MPI_ANY_TAG`). Source is a *world* rank (the comm
/// layer translates group ranks before posting).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MatchSelector {
    pub ctx: u32,
    pub src: Option<usize>,
    pub tag: Option<i32>,
}

impl MatchSelector {
    fn matches(&self, ctx: u32, src: usize, tag: i32) -> bool {
        self.ctx == ctx
            && self.src.map(|s| s == src).unwrap_or(true)
            && self.tag.map(|t| t == tag).unwrap_or(true)
    }
}

/// A receive waiting for a message.
#[derive(Debug)]
pub struct PostedRecv {
    pub recv_token: u64,
    pub sel: MatchSelector,
}

/// A message that arrived before its receive was posted.
#[derive(Debug)]
pub struct UnexpectedMsg {
    pub ctx: u32,
    pub src: usize,
    pub tag: i32,
    /// Hybrid time at which the message became observable here.
    pub depart_vt: f64,
    pub body: UnexpectedBody,
}

#[derive(Debug)]
pub enum UnexpectedBody {
    /// Eager payload: a shared *view* of the sender's pooled wire buffer
    /// (queueing an unexpected message clones an `Arc`, never the bytes)
    /// and the optional synchronous-send token.
    Eager { data: WireBytes, sync_token: Option<u64> },
    /// Rendezvous header: payload still at the sender.
    Rts { nbytes: usize, token: u64, sync_token: Option<u64> },
}

impl UnexpectedMsg {
    /// Payload size for probe's status.
    pub fn nbytes(&self) -> usize {
        match &self.body {
            UnexpectedBody::Eager { data, .. } => data.len(),
            UnexpectedBody::Rts { nbytes, .. } => *nbytes,
        }
    }
}

/// Per-rank matching state. High-watermark counters feed the tool layer.
#[derive(Debug, Default)]
pub struct Matcher {
    posted: VecDeque<PostedRecv>,
    unexpected: VecDeque<UnexpectedMsg>,
    pub posted_hwm: usize,
    pub unexpected_hwm: usize,
    pub match_attempts: u64,
}

impl Matcher {
    pub fn new() -> Matcher {
        Matcher::default()
    }

    /// Post a receive, *after* the caller has checked the unexpected queue
    /// (see [`Matcher::take_unexpected`]).
    pub fn post(&mut self, recv: PostedRecv) {
        self.posted.push_back(recv);
        self.posted_hwm = self.posted_hwm.max(self.posted.len());
    }

    /// An incoming message looks for a posted receive (earliest match
    /// wins). Removes and returns it.
    pub fn take_posted(&mut self, ctx: u32, src: usize, tag: i32) -> Option<PostedRecv> {
        self.match_attempts += 1;
        let idx = self.posted.iter().position(|p| p.sel.matches(ctx, src, tag))?;
        self.posted.remove(idx)
    }

    /// A new receive looks for an already-arrived message (earliest match
    /// wins). Removes and returns it.
    pub fn take_unexpected(&mut self, sel: &MatchSelector) -> Option<UnexpectedMsg> {
        self.match_attempts += 1;
        let idx = self
            .unexpected
            .iter()
            .position(|m| sel.matches(m.ctx, m.src, m.tag))?;
        self.unexpected.remove(idx)
    }

    /// Probe: peek the earliest matching unexpected message.
    pub fn peek_unexpected(&self, sel: &MatchSelector) -> Option<&UnexpectedMsg> {
        self.unexpected.iter().find(|m| sel.matches(m.ctx, m.src, m.tag))
    }

    /// Queue a message that found no posted receive.
    pub fn push_unexpected(&mut self, msg: UnexpectedMsg) {
        self.unexpected.push_back(msg);
        self.unexpected_hwm = self.unexpected_hwm.max(self.unexpected.len());
    }

    /// Cancel a posted receive (`MPI_Cancel`). Returns whether it was still
    /// pending (not yet matched).
    pub fn cancel_posted(&mut self, recv_token: u64) -> bool {
        if let Some(idx) = self.posted.iter().position(|p| p.recv_token == recv_token) {
            self.posted.remove(idx);
            true
        } else {
            false
        }
    }

    pub fn posted_len(&self) -> usize {
        self.posted.len()
    }

    pub fn unexpected_len(&self) -> usize {
        self.unexpected.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn eager(ctx: u32, src: usize, tag: i32) -> UnexpectedMsg {
        UnexpectedMsg {
            ctx,
            src,
            tag,
            depart_vt: 0.0,
            body: UnexpectedBody::Eager { data: WireBytes::empty(), sync_token: None },
        }
    }

    #[test]
    fn wildcard_matching() {
        let any = MatchSelector { ctx: 1, src: None, tag: None };
        assert!(any.matches(1, 5, 9));
        assert!(!any.matches(2, 5, 9));
        let specific = MatchSelector { ctx: 1, src: Some(5), tag: Some(9) };
        assert!(specific.matches(1, 5, 9));
        assert!(!specific.matches(1, 6, 9));
        assert!(!specific.matches(1, 5, 8));
    }

    #[test]
    fn fifo_order_among_equals() {
        let mut m = Matcher::new();
        m.push_unexpected(eager(0, 1, 7));
        m.push_unexpected(eager(0, 1, 7));
        m.post(PostedRecv { recv_token: 100, sel: MatchSelector { ctx: 0, src: Some(2), tag: None } });
        m.post(PostedRecv { recv_token: 101, sel: MatchSelector { ctx: 0, src: None, tag: None } });
        // Incoming from src 2 should match the earliest compatible posted
        // recv — token 100 (not the wildcard posted later).
        let p = m.take_posted(0, 2, 7).unwrap();
        assert_eq!(p.recv_token, 100);
        // And a new recv takes the earliest unexpected.
        let sel = MatchSelector { ctx: 0, src: Some(1), tag: Some(7) };
        assert!(m.take_unexpected(&sel).is_some());
        assert_eq!(m.unexpected_len(), 1);
    }

    #[test]
    fn wildcard_posted_matches_any_source() {
        let mut m = Matcher::new();
        m.post(PostedRecv { recv_token: 1, sel: MatchSelector { ctx: 3, src: None, tag: Some(2) } });
        assert!(m.take_posted(3, 9, 2).is_some());
        assert!(m.take_posted(3, 9, 2).is_none());
    }

    #[test]
    fn context_isolation() {
        let mut m = Matcher::new();
        m.push_unexpected(eager(7, 0, 0));
        let other_ctx = MatchSelector { ctx: 8, src: None, tag: None };
        assert!(m.peek_unexpected(&other_ctx).is_none());
        let same_ctx = MatchSelector { ctx: 7, src: None, tag: None };
        assert_eq!(m.peek_unexpected(&same_ctx).unwrap().src, 0);
    }

    #[test]
    fn cancel_removes_posted() {
        let mut m = Matcher::new();
        m.post(PostedRecv { recv_token: 42, sel: MatchSelector { ctx: 0, src: None, tag: None } });
        assert!(m.cancel_posted(42));
        assert!(!m.cancel_posted(42));
        assert!(m.take_posted(0, 0, 0).is_none());
    }

    #[test]
    fn unexpected_bodies_are_views_in_fifo_order() {
        // Four queued messages share ONE backing buffer (views, not
        // clones) and still come out in arrival order.
        let backing = WireBytes::from_vec((0u8..32).collect());
        let mut m = Matcher::new();
        for i in 0..4 {
            m.push_unexpected(UnexpectedMsg {
                ctx: 0,
                src: 1,
                tag: 7,
                depart_vt: i as f64,
                body: UnexpectedBody::Eager { data: backing.slice(i * 8, 8), sync_token: None },
            });
        }
        assert_eq!(backing.ref_count(), 5, "queued bodies must share, not clone");
        let sel = MatchSelector { ctx: 0, src: Some(1), tag: Some(7) };
        for i in 0..4u8 {
            let msg = m.take_unexpected(&sel).expect("message queued");
            match msg.body {
                UnexpectedBody::Eager { data, .. } => {
                    assert_eq!(data[0], i * 8, "FIFO order violated");
                    assert_eq!(data.len(), 8);
                }
                UnexpectedBody::Rts { .. } => unreachable!(),
            }
        }
        assert_eq!(backing.ref_count(), 1);
    }

    #[test]
    fn watermarks_track() {
        let mut m = Matcher::new();
        for i in 0..5 {
            m.push_unexpected(eager(0, i, 0));
        }
        let sel = MatchSelector { ctx: 0, src: None, tag: None };
        m.take_unexpected(&sel);
        assert_eq!(m.unexpected_hwm, 5);
        assert_eq!(m.unexpected_len(), 4);
    }
}
