//! Point-to-point communication (MPI-4.0 §3): envelopes, the matching
//! engine (posted-receive queue + unexpected-message queue), the four send
//! modes, immediate operations, probe/mprobe, and the progress engine that
//! drives everything (collectives and IO ride on the same machinery).
//!
//! Threading model: each simulated rank is an OS thread; all of a rank's
//! MPI state ([`RankCtx`]) is confined to that thread (`Rc`/`RefCell`), and
//! the only cross-thread channel is the fabric mailbox. Every user buffer
//! write happens on the owning rank's thread inside its own progress calls,
//! which is what makes the small amount of raw-pointer buffer capture sound
//! under the standard's "don't touch the buffer until completion" contract.

pub mod buffer;
pub mod engine;
pub mod matcher;
pub mod partitioned;
pub mod state;

pub use buffer::{RawBuf, RawBufMut};
pub use engine::{
    abandon_recv, cancel_recv, detach_deferred_send, improbe, io_done, iprobe, mprobe, mrecv,
    post_recv, probe, progress, quiesce_flow, recv_done, rma_done, send_done, start_io, start_rma,
    take_io_result, take_recv_result, take_rma_result, take_send_done, wait_for, IoKind, Message,
    RmaKind, RndvStaging, SendMode, SendParams,
};
pub use matcher::{Matcher, MatchSelector};
pub use state::{
    IoProgress, Progressable, RankCtx, RecvProgress, RecvState, RmaProgress, SendState, Status,
    WindowMem,
};
