//! The progress engine: send/receive posting, packet handling, blocking
//! waits, probe and matched-probe.
//!
//! Design notes:
//! * **Eager** send payloads are packed at post time into pooled wire
//!   buffers ([`crate::transport::BufferPool`]) and shared, not copied,
//!   all the way to the receiver's unpack. Contiguous typemaps pack with
//!   a single slice append that models NIC DMA injection — the zero-copy
//!   fast path; only non-contiguous staging charges the fabric's
//!   `wire_bytes_copied` counter.
//! * **Rendezvous** sends with [`RndvStaging::Deferred`] pack nothing at
//!   post time: the buffer address is parked and packing happens when the
//!   CTS arrives. Only senders whose buffer provably outlives the
//!   operation use it — blocking sends (the call waits), persistent
//!   templates (blocking `Drop`) and partitioned sends (blocking `Drop`).
//!   Everything else — plain `isend` (its `Request` may be dropped
//!   without completing) and the collective arena (rewritten by later
//!   rounds) — uses [`RndvStaging::Staged`].
//! * All receive-buffer writes happen on the owning rank's thread inside
//!   [`progress`] / [`wait_for`].
//! * `advance` of registered [`Progressable`]s (nonblocking collectives,
//!   collective IO) runs at the end of every progress turn; they must not
//!   re-enter the engine.
//! * **One-sided operations** are real transport traffic, not
//!   shared-memory shortcuts: the origin injects an `Rma*` packet
//!   ([`start_rma`]) naming the window id and byte offset, and the
//!   *target's* engine applies it to the exposed segment registered in
//!   [`RankCtx::windows`](super::state::WindowMem) when its own progress
//!   loop processes the packet — the passive-target progress rule of a
//!   software-emulated RDMA stack. The per-rank engine thread serializes
//!   all RMA applications on a target, which is what makes accumulate /
//!   fetch-and-op / compare-and-swap atomic across origins. Completion
//!   flows back as `RmaAck`/`RmaGetResp` and flips the origin's
//!   [`RmaProgress`](super::state::RmaProgress) entry to `Done`.

use super::buffer::{RawBuf, RawBufMut};
use super::matcher::{MatchSelector, PostedRecv, UnexpectedBody, UnexpectedMsg};
use super::state::{
    IoProgress, RankCtx, RecvProgress, RecvState, RmaProgress, SendState, Status, WindowMem,
    BSEND_OVERHEAD,
};
use crate::datatype::{pack, pack_size, unpack, validate_send_span, Datatype, TypeMap};
use crate::group::Group;
use crate::op::{Op, OpKind};
use crate::transport::{Packet, PacketKind, PoolHandle, WireBytes};
use crate::{mpi_err, Result};
use std::rc::Rc;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

/// The four MPI send modes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SendMode {
    Standard,
    Synchronous,
    Buffered,
    /// Ready mode: the standard makes it erroneous unless the receive is
    /// already posted; this implementation delivers eagerly (a legal
    /// implementation of the erroneous case) and never fails remotely.
    Ready,
}

/// How a rendezvous-size send treats its payload between post and CTS.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RndvStaging {
    /// Capture only the buffer address; pack when the CTS arrives (the
    /// zero-copy path). The caller must *structurally guarantee* the
    /// buffer stays live and untouched until the send completes (e.g. by
    /// blocking in the same call, or by blocking in `Drop`).
    Deferred,
    /// Pack at post time into a pooled wire buffer and park the packed
    /// bytes. For senders that cannot guarantee the source past the post
    /// call (droppable immediate requests, collective arena rounds).
    Staged,
}

/// Everything a send needs. `dst_world` is a world rank (comm layers
/// translate); `ctx_id` selects the communicator context.
pub struct SendParams<'a> {
    pub ctx_id: u32,
    pub dst_world: usize,
    pub tag: i32,
    pub buf: &'a [u8],
    pub count: usize,
    pub dtype: &'a Datatype,
    pub mode: SendMode,
    pub staging: RndvStaging,
}

/// Start a send. Returns `None` if it completed locally (eager standard /
/// buffered / ready), or `Some(token)` to wait on (synchronous or
/// rendezvous).
pub fn start_send(ctx: &RankCtx, p: SendParams<'_>) -> Result<Option<u64>> {
    p.dtype.require_committed()?;
    ctx.counters.sends_started.set(ctx.counters.sends_started.get() + 1);
    let map = p.dtype.map();
    let nbytes = pack_size(map, p.count);

    let eager =
        ctx.fabric.model.is_eager(nbytes) || matches!(p.mode, SendMode::Buffered | SendMode::Ready);

    if matches!(p.mode, SendMode::Buffered) {
        let pool = ctx.bsend.borrow_mut();
        let need = nbytes + BSEND_OVERHEAD;
        if pool.in_use + need > pool.capacity {
            return Err(mpi_err!(
                Buffer,
                "bsend of {nbytes} bytes exceeds attached buffer ({} of {} in use)",
                pool.in_use,
                pool.capacity
            ));
        }
        // The eager fabric delivers synchronously, so the reservation is
        // released as soon as the packet is queued below.
    }

    let now = ctx.clock.now_ns();
    if eager {
        let wire = pack_wire(ctx, map, p.buf, p.count)?;
        let sync_token = if matches!(p.mode, SendMode::Synchronous) {
            Some(ctx.fresh_token())
        } else {
            None
        };
        let dst = p.dst_world;
        if ctx.flow.enabled() {
            // Credit-based flow control (docs/FLOWCONTROL.md). When the
            // peer's pending queue already holds a full complement of
            // parked payloads, new sends demote to rendezvous — RTS/CTS
            // self-limits instead of parking more data. Buffered and
            // ready sends must complete locally, so they never demote;
            // they just park (the payload is packed, the user buffer is
            // already free).
            let demotable = matches!(p.mode, SendMode::Standard | SendMode::Synchronous);
            if demotable && ctx.flow.parked_payloads(dst) >= ctx.flow.cfg.pending_cap {
                ctx.fabric.stats.eager_demoted.fetch_add(1, Ordering::Relaxed);
                let token = ctx.fresh_token();
                ctx.sends.borrow_mut().insert(token, SendState::AwaitCts { staged: wire });
                let rts = PacketKind::Rts {
                    ctx: p.ctx_id,
                    tag: p.tag,
                    nbytes,
                    token,
                    sync_token: None,
                };
                let prepared = ctx.fabric.prepare(ctx.world_rank, dst, now, rts);
                if ctx.flow.has_pending(dst) {
                    // FIFO behind the parked packets: shipping the RTS
                    // around the queue would break non-overtaking.
                    ctx.flow.pending(dst).borrow_mut().push_back(prepared);
                } else {
                    ctx.fabric.ship(prepared);
                }
                return Ok(Some(token));
            }
            let kind = PacketKind::Eager { ctx: p.ctx_id, tag: p.tag, data: wire, sync_token };
            let prepared = ctx.fabric.prepare(ctx.world_rank, dst, now, kind);
            let refused = if ctx.flow.has_pending(dst) {
                // Something is already parked for this peer: queue behind
                // it unconditionally, or this send would overtake.
                Some(prepared)
            } else if ctx.flow.take_credit(dst) {
                match ctx.fabric.try_ship(prepared) {
                    Ok(_) => None,
                    Err(p) => {
                        // Mailbox full: hand the credit back; the flush
                        // path re-takes it when space opens.
                        ctx.flow.give_credit(dst);
                        Some(p)
                    }
                }
            } else {
                Some(prepared)
            };
            if let Some(p) = refused {
                ctx.fabric.stats.credits_stalled.fetch_add(1, Ordering::Relaxed);
                ctx.flow.pending(dst).borrow_mut().push_back(p);
                ctx.flow.note_parked_payload(dst, 1);
            }
        } else {
            ctx.fabric.send(
                ctx.world_rank,
                dst,
                now,
                PacketKind::Eager { ctx: p.ctx_id, tag: p.tag, data: wire, sync_token },
            );
        }
        if let Some(tok) = sync_token {
            ctx.sends.borrow_mut().insert(tok, SendState::AwaitAck);
            Ok(Some(tok))
        } else {
            Ok(None)
        }
    } else {
        // Rendezvous: ship the header, park the payload (or just its
        // address). Completion is at CTS (which implies the receive
        // matched, so this also covers the synchronous-mode contract).
        let token = ctx.fresh_token();
        let state = match p.staging {
            RndvStaging::Staged => {
                SendState::AwaitCts { staged: pack_wire(ctx, map, p.buf, p.count)? }
            }
            RndvStaging::Deferred => {
                // Packing happens at CTS; surface span errors now, while
                // the caller can still handle them.
                validate_send_span(map, p.buf.len(), p.count)?;
                SendState::AwaitCtsDeferred {
                    buf: RawBuf::from_slice(p.buf),
                    count: p.count,
                    dtype: p.dtype.clone(),
                }
            }
        };
        ctx.sends.borrow_mut().insert(token, state);
        let rts = PacketKind::Rts { ctx: p.ctx_id, tag: p.tag, nbytes, token, sync_token: None };
        if ctx.flow.enabled() && ctx.flow.has_pending(p.dst_world) {
            // The RTS lives in the same matching domain as any parked
            // eager packet: it must queue behind them (header-only, so it
            // does not count toward the payload demotion threshold).
            let prepared = ctx.fabric.prepare(ctx.world_rank, p.dst_world, now, rts);
            ctx.flow.pending(p.dst_world).borrow_mut().push_back(prepared);
        } else {
            ctx.fabric.send(ctx.world_rank, p.dst_world, now, rts);
        }
        Ok(Some(token))
    }
}

/// Detach a deferred rendezvous send from its caller-owned buffer: if the
/// send is still awaiting CTS with packing deferred, pack *now* — while
/// the buffer is provably still live — and park the staged bytes instead.
/// Error-path cleanup: callers that can no longer guarantee the buffer
/// past the current call (a blocking wait that returned an error, a
/// template drop whose rescue wait failed) must call this before letting
/// the buffer go, or a late CTS would pack from freed memory. No-op for
/// any other send state.
pub fn detach_deferred_send(ctx: &RankCtx, token: u64) {
    let state = ctx.sends.borrow_mut().remove(&token);
    match state {
        Some(SendState::AwaitCtsDeferred { buf, count, dtype }) => {
            let staged = pack_wire(ctx, dtype.map(), unsafe { buf.as_slice() }, count)
                .unwrap_or_else(|_| WireBytes::empty());
            ctx.sends.borrow_mut().insert(token, SendState::AwaitCts { staged });
        }
        Some(other) => {
            ctx.sends.borrow_mut().insert(token, other);
        }
        None => {}
    }
}

/// Error-path cleanup for a receive whose buffer can no longer be
/// guaranteed: cancel it if still posted, then unconditionally drop its
/// engine state so a late delivery fails loudly (`Intern` error at the
/// RData/eager handler) instead of writing through the dangling buffer
/// pointer.
pub fn abandon_recv(ctx: &RankCtx, token: u64) {
    let _ = cancel_recv(ctx, token);
    ctx.recvs.borrow_mut().remove(&token);
    ctx.pending_rndv.borrow_mut().remove(&token);
}

/// Pack `count` elements into a pooled wire buffer and freeze it for
/// sharing. Contiguous layouts are a single slice append (DMA-modeled
/// injection, not charged); non-contiguous staging charges the fabric's
/// `wire_bytes_copied` counter.
pub(crate) fn pack_wire(
    ctx: &RankCtx,
    map: &TypeMap,
    src: &[u8],
    count: usize,
) -> Result<WireBytes> {
    let mut wire = ctx.fabric.pool.take(pack_size(map, count));
    pack(map, src, count, &mut wire)?;
    if !map.is_contiguous() {
        ctx.fabric.pool.count_copied(wire.len());
    }
    Ok(wire.freeze())
}

// ---------------- one-sided (RMA) ----------------

/// One one-sided operation as the engine sees it: window and byte offset
/// already resolved, payload already packed onto a pooled wire buffer.
#[derive(Debug)]
pub enum RmaKind {
    /// Write `data` at the target offset.
    Put { data: WireBytes },
    /// Read `nbytes` from the target offset.
    Get { nbytes: usize },
    /// Combine `data` (`count` packed elements of `map`) with the target
    /// bytes using the predefined `op`; `fetch` returns the pre-op bytes.
    Acc { data: WireBytes, count: usize, map: Arc<TypeMap>, op: OpKind, fetch: bool },
    /// Single-element compare-and-swap; `data` = origin ‖ compare bytes.
    Cas { data: WireBytes },
}

/// Expose `size` bytes of window memory under `win` on this rank. The
/// segment is zero-initialized (`MPI_Win_allocate` semantics).
pub fn register_window(ctx: &RankCtx, win: u32, size: usize) {
    ctx.windows
        .borrow_mut()
        .insert(win, Rc::new(WindowMem { seg: std::cell::RefCell::new(vec![0u8; size]) }));
}

/// Retire a window's local segment (`MPI_Win_free`, after the closing
/// barrier has guaranteed no more traffic can target it).
pub fn unregister_window(ctx: &RankCtx, win: u32) {
    ctx.windows.borrow_mut().remove(&win);
}

/// This rank's exposed segment for `win` (owner-side `with_local` access).
pub fn window_local(ctx: &RankCtx, win: u32) -> Option<Rc<WindowMem>> {
    ctx.windows.borrow().get(&win).cloned()
}

/// Inject one one-sided operation toward `dst_world` and return the token
/// its completion (the target's ack/response) will carry. Local targets go
/// through the fabric too — one uniform path, one ordering domain.
pub fn start_rma(ctx: &RankCtx, dst_world: usize, win: u32, off: usize, kind: RmaKind) -> u64 {
    let token = ctx.fresh_token();
    ctx.rma.borrow_mut().insert(token, RmaProgress::Pending);
    let pk = match kind {
        RmaKind::Put { data } => PacketKind::RmaPut { win, off, data, token },
        RmaKind::Get { nbytes } => PacketKind::RmaGet { win, off, nbytes, token },
        RmaKind::Acc { data, count, map, op, fetch } => {
            PacketKind::RmaAcc { win, off, data, count, map, op, fetch, token }
        }
        RmaKind::Cas { data } => PacketKind::RmaCas { win, off, data, token },
    };
    let now = ctx.clock.now_ns();
    ctx.fabric.send(ctx.world_rank, dst_world, now, pk);
    token
}

/// Has the target completed this one-sided op? Non-consuming, drives no
/// progress; a consumed (absent) token reads as done.
pub fn rma_done(ctx: &RankCtx, token: u64) -> bool {
    !matches!(ctx.rma.borrow().get(&token), Some(RmaProgress::Pending))
}

/// Take a completed one-sided op's response payload (empty for put/acc).
pub fn take_rma_result(ctx: &RankCtx, token: u64) -> Result<WireBytes> {
    let mut rma = ctx.rma.borrow_mut();
    match rma.remove(&token) {
        Some(RmaProgress::Done(data)) => Ok(data),
        Some(p @ RmaProgress::Pending) => {
            rma.insert(token, p);
            Err(mpi_err!(Intern, "take of incomplete rma op {token}"))
        }
        None => Err(mpi_err!(Request, "unknown rma op token {token}")),
    }
}

/// Look up a window a remote op targets, or fail loudly: an op arriving
/// for an unregistered window means the `MPI_Win_free` protocol (flush
/// everywhere, then barrier, then retire) was violated.
fn rma_window(ctx: &RankCtx, win: u32) -> Result<Rc<WindowMem>> {
    window_local(ctx, win)
        .ok_or_else(|| mpi_err!(Win, "RMA op targets window {win:#x} not exposed on this rank"))
}

/// Bounds-check an RMA span against the exposed segment.
fn rma_span(seg_len: usize, off: usize, nbytes: usize) -> Result<std::ops::Range<usize>> {
    match off.checked_add(nbytes) {
        Some(end) if end <= seg_len => Ok(off..end),
        _ => Err(mpi_err!(
            RmaRange,
            "RMA span of {nbytes} bytes at offset {off} exceeds window segment of {seg_len}"
        )),
    }
}

/// Copy target bytes onto a pooled wire buffer — the NIC-read half of a
/// get/fetch (DMA-modeled, so not charged to `wire_bytes_copied`).
fn read_segment(ctx: &RankCtx, seg: &[u8], range: std::ops::Range<usize>) -> WireBytes {
    let mut wire = ctx.fabric.pool.take(range.len());
    wire.extend_from_slice(&seg[range]);
    wire.freeze()
}

/// Ship a reply packet originated *inside* the packet handler. Payload
/// replies (get responses) may hit mailbox backpressure; they are
/// token-addressed and order-free, so a refused one parks in
/// `flow.deferred_tx` and retries each progress turn — the handler never
/// blocks and never recurses into the engine.
fn reply_from_handler(ctx: &RankCtx, to: usize, kind: PacketKind) {
    let now = ctx.clock.now_ns();
    let prepared = ctx.fabric.prepare(ctx.world_rank, to, now, kind);
    if let Err(p) = ctx.fabric.try_ship(prepared) {
        ctx.flow.deferred_tx.borrow_mut().push(p);
    }
}

/// Record a target's completion reply against the origin-side token.
fn rma_complete(ctx: &RankCtx, token: u64, data: WireBytes) -> Result<()> {
    match ctx.rma.borrow_mut().insert(token, RmaProgress::Done(data)) {
        Some(RmaProgress::Pending) => Ok(()),
        _ => Err(mpi_err!(Intern, "RMA completion for token {token} not pending")),
    }
}

// ---------------- MPI-IO over the wire ----------------

/// One IO operation as the engine injects it toward the file server:
/// metadata ops (open/close/resize/shared-pointer arithmetic), a
/// view-scattered write, or a view-gathered read. See `io::server` for
/// the server-side application.
#[derive(Debug)]
pub enum IoKind {
    /// A metadata op (`io::server::meta_op` codes); `arg` is op-specific.
    Meta { path: String, op: u8, arg: u64 },
    /// Scatter `data` through the (displacement, filetype) view starting
    /// at logical byte `lo`.
    Write { path: String, disp: u64, map: Arc<TypeMap>, lo: u64, data: WireBytes },
    /// Gather `nbytes` through the view starting at logical byte `lo`
    /// (short at EOF).
    Read { path: String, disp: u64, map: Arc<TypeMap>, lo: u64, nbytes: usize },
}

/// Inject one IO operation toward the file-server rank and return the
/// token its completion (`IoDone`/`IoData`) will carry. Like RMA, local
/// servers go through the fabric too — one uniform path, so chaos
/// delay/reorder and the packet cost model apply to every file access.
pub fn start_io(ctx: &RankCtx, server_world: usize, kind: IoKind) -> u64 {
    let token = ctx.fresh_token();
    ctx.io.borrow_mut().insert(token, IoProgress::Pending);
    ctx.fabric.stats.io_ops_inflight.fetch_add(1, Ordering::Relaxed);
    let pk = match kind {
        IoKind::Meta { path, op, arg } => PacketKind::IoMeta { path, op, arg, token },
        IoKind::Write { path, disp, map, lo, data } => {
            PacketKind::IoWrite { path, disp, map, lo, data, token }
        }
        IoKind::Read { path, disp, map, lo, nbytes } => {
            PacketKind::IoRead { path, disp, map, lo, nbytes, token }
        }
    };
    let now = ctx.clock.now_ns();
    ctx.fabric.send(ctx.world_rank, server_world, now, pk);
    token
}

/// Has the file server completed this IO op? Non-consuming, drives no
/// progress; a consumed (absent) token reads as done.
pub fn io_done(ctx: &RankCtx, token: u64) -> bool {
    !matches!(ctx.io.borrow().get(&token), Some(IoProgress::Pending))
}

/// Take a completed IO op's result: the response payload (read data;
/// empty for writes and metadata ops) and the scalar value (bytes
/// written, file size, old shared-pointer — op-specific).
pub fn take_io_result(ctx: &RankCtx, token: u64) -> Result<(WireBytes, u64)> {
    let mut io = ctx.io.borrow_mut();
    match io.remove(&token) {
        Some(IoProgress::Done { data, value }) => Ok((data, value)),
        Some(IoProgress::Failed(e)) => Err(e),
        Some(p @ IoProgress::Pending) => {
            io.insert(token, p);
            Err(mpi_err!(Intern, "take of incomplete io op {token}"))
        }
        None => Err(mpi_err!(Request, "unknown io op token {token}")),
    }
}

/// Record the file server's completion reply against the origin-side
/// token. A nonzero `code` is the wire form of the server-side
/// `ErrorClass`; it surfaces when the result is taken.
fn io_complete(ctx: &RankCtx, token: u64, data: WireBytes, value: u64, code: i32) -> Result<()> {
    ctx.fabric.stats.io_ops_inflight.fetch_sub(1, Ordering::Relaxed);
    let state = if code == 0 {
        IoProgress::Done { data, value }
    } else {
        let class = crate::error::ErrorClass::from_code(code);
        IoProgress::Failed(crate::error::MpiError::new(
            class,
            format!("file server: {}", class.as_str()),
        ))
    };
    match ctx.io.borrow_mut().insert(token, state) {
        Some(IoProgress::Pending) => Ok(()),
        _ => Err(mpi_err!(Intern, "IO completion for token {token} not pending")),
    }
}

/// Post a receive. `src_world`/`tag` of `None` are the wildcards. Returns
/// the receive token to wait on.
pub fn post_recv(
    ctx: &RankCtx,
    ctx_id: u32,
    src_world: Option<usize>,
    tag: Option<i32>,
    buf: RawBufMut,
    count: usize,
    dtype: Datatype,
    group: Group,
) -> Result<u64> {
    dtype.require_committed()?;
    ctx.counters.recvs_posted.set(ctx.counters.recvs_posted.get() + 1);
    let token = ctx.fresh_token();
    ctx.recvs.borrow_mut().insert(
        token,
        RecvState { buf, count, dtype, group, progress: RecvProgress::Pending },
    );
    let sel = MatchSelector { ctx: ctx_id, src: src_world, tag };
    // Unexpected queue first (earliest arrival wins).
    let hit = ctx.matcher.borrow_mut().take_unexpected(&sel);
    match hit {
        Some(msg) => match_arrived(ctx, token, msg),
        None => {
            ctx.matcher.borrow_mut().post(PostedRecv { recv_token: token, sel });
            Ok(())
        }
    }?;
    Ok(token)
}

/// An arrived message (either from the unexpected queue at post time, or a
/// fresh packet that found a posted receive) meets its receive.
fn match_arrived(ctx: &RankCtx, recv_token: u64, msg: UnexpectedMsg) -> Result<()> {
    ctx.counters.messages_matched.set(ctx.counters.messages_matched.get() + 1);
    ctx.clock.advance_to(msg.depart_vt);
    if ctx.fabric.trace.enabled() {
        ctx.fabric.trace.record(
            ctx.world_rank,
            ctx.clock.now_ns(),
            "match",
            format!("src r{} tag {} ctx {} {}B", msg.src, msg.tag, msg.ctx, msg.nbytes()),
        );
    }
    match msg.body {
        UnexpectedBody::Eager { data, sync_token } => {
            if let Some(tok) = sync_token {
                let now = ctx.clock.now_ns();
                ctx.fabric.send(ctx.world_rank, msg.src, now, PacketKind::SsendAck { token: tok });
            }
            // The credit goes home at *delivery into a user buffer*, not
            // at arrival — the window is what bounds the unexpected
            // queue. Returns are batched; the remainder flushes at
            // closure (`quiesce_flow`).
            credit_delivery(ctx, msg.src);
            deliver_payload(ctx, recv_token, msg.src, msg.tag, &data)
        }
        UnexpectedBody::Rts { token, sync_token: _, .. } => {
            // Remember the envelope for the final status, send CTS; payload
            // arrives as RData addressed to `recv_token`.
            if let Some(rs) = ctx.recvs.borrow_mut().get_mut(&recv_token) {
                // Stash envelope in the state: encode via a pending
                // marker — source/tag are recorded at delivery from the
                // RData packet's metadata, so park them here.
                rs.progress = RecvProgress::Pending;
            }
            ctx.pending_rndv.borrow_mut().insert(recv_token, (msg.src, msg.tag));
            let now = ctx.clock.now_ns();
            ctx.fabric.send(ctx.world_rank, msg.src, now, PacketKind::Cts { token, recv_token });
            Ok(())
        }
    }
}

/// Unpack wire bytes into the receive's buffer and complete it. Reads
/// directly from the shared packet view — the payload is not duplicated
/// between arrival and unpack. The contiguous unpack is the DMA-modeled
/// single copy into the user buffer; non-contiguous scatter charges
/// `wire_bytes_copied`.
fn deliver_payload(
    ctx: &RankCtx,
    recv_token: u64,
    src_world: usize,
    tag: i32,
    data: &WireBytes,
) -> Result<()> {
    let mut recvs = ctx.recvs.borrow_mut();
    let rs = recvs
        .get_mut(&recv_token)
        .ok_or_else(|| mpi_err!(Intern, "recv token {recv_token} vanished"))?;
    let capacity = pack_size(rs.dtype.map(), rs.count);
    let source = rs.group.rank_of(src_world).map(|r| r as i32).unwrap_or(-1);
    if data.len() > capacity {
        rs.progress = RecvProgress::Failed(mpi_err!(
            Truncate,
            "message of {} bytes truncated to receive capacity {capacity}",
            data.len()
        ));
        return Ok(());
    }
    let elem = rs.dtype.size();
    let whole = if elem == 0 { 0 } else { data.len() / elem };
    let buf = unsafe { rs.buf.as_slice_mut() };
    let result = unpack(rs.dtype.map(), data, buf, whole).and_then(|used| {
        if !rs.dtype.map().is_contiguous() {
            ctx.fabric.pool.count_copied(used);
        }
        // Partial trailing element: only well-defined for contiguous
        // layouts (bytes land in order); for noncontiguous layouts the
        // remainder is dropped and the status still reports actual bytes.
        let rem = data.len() - used;
        if rem > 0 && rs.dtype.map().is_contiguous() {
            buf[used..used + rem].copy_from_slice(&data[used..]);
        }
        Ok(())
    });
    rs.progress = match result {
        Ok(()) => RecvProgress::Done(Status { source, tag, bytes: data.len(), cancelled: false }),
        Err(e) => RecvProgress::Failed(e),
    };
    Ok(())
}

/// Handle one inbound packet.
fn handle_packet(ctx: &RankCtx, pkt: Packet) -> Result<()> {
    // Abort wake-up marker.
    if pkt.src == usize::MAX {
        ctx.fabric.check_abort();
        return Ok(());
    }
    ctx.clock.advance_to(pkt.depart_vt);
    match pkt.kind {
        PacketKind::Eager { ctx: ctx_id, tag, data, sync_token } => {
            let posted = ctx.matcher.borrow_mut().take_posted(ctx_id, pkt.src, tag);
            match posted {
                Some(p) => match_arrived(
                    ctx,
                    p.recv_token,
                    UnexpectedMsg {
                        ctx: ctx_id,
                        src: pkt.src,
                        tag,
                        depart_vt: pkt.depart_vt,
                        body: UnexpectedBody::Eager { data, sync_token },
                    },
                ),
                None => {
                    ctx.matcher.borrow_mut().push_unexpected(UnexpectedMsg {
                        ctx: ctx_id,
                        src: pkt.src,
                        tag,
                        depart_vt: pkt.depart_vt,
                        body: UnexpectedBody::Eager { data, sync_token },
                    });
                    Ok(())
                }
            }
        }
        PacketKind::Rts { ctx: ctx_id, tag, nbytes, token, sync_token } => {
            let posted = ctx.matcher.borrow_mut().take_posted(ctx_id, pkt.src, tag);
            match posted {
                Some(p) => match_arrived(
                    ctx,
                    p.recv_token,
                    UnexpectedMsg {
                        ctx: ctx_id,
                        src: pkt.src,
                        tag,
                        depart_vt: pkt.depart_vt,
                        body: UnexpectedBody::Rts { nbytes, token, sync_token },
                    },
                ),
                None => {
                    ctx.matcher.borrow_mut().push_unexpected(UnexpectedMsg {
                        ctx: ctx_id,
                        src: pkt.src,
                        tag,
                        depart_vt: pkt.depart_vt,
                        body: UnexpectedBody::Rts { nbytes, token, sync_token },
                    });
                    Ok(())
                }
            }
        }
        PacketKind::Cts { token, recv_token } => {
            let state = ctx.sends.borrow_mut().remove(&token);
            let data = match state {
                // Staged: the packed bytes were parked at post; ship the
                // same shared buffer — no copy, no allocation.
                Some(SendState::AwaitCts { staged }) => staged,
                // Deferred: the zero-copy path packs here, straight from
                // the (contract-protected) user buffer into a pooled wire
                // buffer. The span was validated at post time.
                Some(SendState::AwaitCtsDeferred { buf, count, dtype }) => {
                    pack_wire(ctx, dtype.map(), unsafe { buf.as_slice() }, count)?
                }
                other => {
                    return Err(mpi_err!(
                        Intern,
                        "CTS for send token {token} in state {other:?}"
                    ))
                }
            };
            ctx.sends.borrow_mut().insert(token, SendState::Done);
            // Rendezvous data is receiver-paced (the CTS is the credit)
            // but still occupies a mailbox payload slot; a full mailbox
            // defers it rather than over-admitting or recursing.
            reply_from_handler(ctx, pkt.src, PacketKind::RData { recv_token, data });
            Ok(())
        }
        PacketKind::RData { recv_token, data } => {
            let (src, tag) = ctx
                .pending_rndv
                .borrow_mut()
                .remove(&recv_token)
                .ok_or_else(|| mpi_err!(Intern, "RData for unknown recv token {recv_token}"))?;
            deliver_payload(ctx, recv_token, src, tag, &data)
        }
        PacketKind::SsendAck { token } => {
            ctx.sends.borrow_mut().insert(token, SendState::Done);
            Ok(())
        }
        // ---- one-sided ops applied on the target's own thread ----
        PacketKind::RmaPut { win, off, data, token } => {
            let mem = rma_window(ctx, win)?;
            {
                let mut seg = mem.seg.borrow_mut();
                let range = rma_span(seg.len(), off, data.len())?;
                // DMA-modeled NIC write into exposed memory: not charged.
                seg[range].copy_from_slice(&data);
            }
            reply_from_handler(ctx, pkt.src, PacketKind::RmaAck { token });
            Ok(())
        }
        PacketKind::RmaGet { win, off, nbytes, token } => {
            let mem = rma_window(ctx, win)?;
            let data = {
                let seg = mem.seg.borrow();
                let range = rma_span(seg.len(), off, nbytes)?;
                read_segment(ctx, &seg, range)
            };
            reply_from_handler(ctx, pkt.src, PacketKind::RmaGetResp { token, data });
            Ok(())
        }
        PacketKind::RmaAcc { win, off, data, count, map, op, fetch, token } => {
            let mem = rma_window(ctx, win)?;
            let old = {
                let mut seg = mem.seg.borrow_mut();
                let range = rma_span(seg.len(), off, data.len())?;
                let old = fetch.then(|| read_segment(ctx, &seg, range.clone()));
                Op::Predefined(op).apply(&map, &data, &mut seg[range], count)?;
                old
            };
            match old {
                Some(data) => reply_from_handler(ctx, pkt.src, PacketKind::RmaGetResp { token, data }),
                None => reply_from_handler(ctx, pkt.src, PacketKind::RmaAck { token }),
            }
            Ok(())
        }
        PacketKind::RmaCas { win, off, data, token } => {
            let n = data.len() / 2;
            let (origin, compare) = (data.slice(0, n), data.slice(n, n));
            let old = {
                let mem = rma_window(ctx, win)?;
                let mut seg = mem.seg.borrow_mut();
                let range = rma_span(seg.len(), off, n)?;
                let old = read_segment(ctx, &seg, range.clone());
                if seg[range.clone()] == compare[..] {
                    seg[range].copy_from_slice(&origin);
                }
                old
            };
            reply_from_handler(ctx, pkt.src, PacketKind::RmaGetResp { token, data: old });
            Ok(())
        }
        PacketKind::RmaAck { token } => rma_complete(ctx, token, WireBytes::empty()),
        PacketKind::RmaGetResp { token, data } => rma_complete(ctx, token, data),
        // ---- MPI-IO ops applied on the file-server rank's own thread ----
        PacketKind::IoMeta { path, op, arg, token } => {
            let (value, code) = crate::io::server::serve_meta(ctx, &path, op, arg);
            reply_from_handler(ctx, pkt.src, PacketKind::IoDone { token, value, code });
            Ok(())
        }
        PacketKind::IoWrite { path, disp, map, lo, data, token } => {
            let (value, code) = crate::io::server::serve_write(ctx, &path, disp, &map, lo, &data);
            reply_from_handler(ctx, pkt.src, PacketKind::IoDone { token, value, code });
            Ok(())
        }
        PacketKind::IoRead { path, disp, map, lo, nbytes, token } => {
            match crate::io::server::serve_read(ctx, &path, disp, &map, lo, nbytes) {
                Ok(data) => reply_from_handler(ctx, pkt.src, PacketKind::IoData { token, data }),
                Err(e) => reply_from_handler(
                    ctx,
                    pkt.src,
                    PacketKind::IoDone { token, value: 0, code: e.code() },
                ),
            }
            Ok(())
        }
        PacketKind::IoDone { token, value, code } => {
            io_complete(ctx, token, WireBytes::empty(), value, code)
        }
        PacketKind::IoData { token, data } => {
            let value = data.len() as u64;
            io_complete(ctx, token, data, value, 0)
        }
        PacketKind::CreditReturn { n } => {
            ctx.flow.returned(pkt.src, n);
            // Fresh liquidity: ship whatever was parked for that peer.
            flush_peer(ctx, pkt.src);
            Ok(())
        }
    }
}

// ---------------- eager flow control (docs/FLOWCONTROL.md) ----------------

/// Receiver side: one eager message from `src` just reached a user
/// buffer. Accrue the owed credit and ship a batched `CreditReturn` when
/// one is due (control packets never block on mailbox capacity).
fn credit_delivery(ctx: &RankCtx, src: usize) {
    if !ctx.flow.enabled() {
        return;
    }
    if let Some(n) = ctx.flow.accrue_owed(src) {
        let now = ctx.clock.now_ns();
        ctx.fabric.send(ctx.world_rank, src, now, PacketKind::CreditReturn { n });
    }
}

/// Drain `peer`'s pending queue front-to-back: payload entries need a
/// credit *and* mailbox space, control entries (demoted RTS) ship
/// unconditionally. Stops at the first entry that cannot go — anything
/// behind it must wait to preserve non-overtaking.
fn flush_peer(ctx: &RankCtx, peer: usize) {
    loop {
        let is_payload = {
            let q = ctx.flow.pending(peer).borrow();
            match q.front() {
                None => return,
                Some(p) => p.kind().counts_against_capacity(),
            }
        };
        if is_payload {
            if !ctx.flow.take_credit(peer) {
                return;
            }
            let p = ctx.flow.pending(peer).borrow_mut().pop_front().unwrap();
            match ctx.fabric.try_ship(p) {
                Ok(_) => ctx.flow.note_parked_payload(peer, -1),
                Err(p) => {
                    ctx.flow.give_credit(peer);
                    ctx.flow.pending(peer).borrow_mut().push_front(p);
                    return;
                }
            }
        } else {
            let p = ctx.flow.pending(peer).borrow_mut().pop_front().unwrap();
            ctx.fabric.ship(p);
        }
    }
}

/// One turn of sender-side flow work: retry deferred in-handler replies,
/// then every peer's parked sends. No-ops (two empty checks) when flow
/// control is off or nothing is waiting — the uncontended path stays flat.
fn flush_flow(ctx: &RankCtx) {
    if !ctx.flow.enabled() {
        return;
    }
    if !ctx.flow.deferred_tx.borrow().is_empty() {
        let deferred = ctx.flow.deferred_tx.take();
        let mut still = Vec::new();
        for p in deferred {
            if let Err(p) = ctx.fabric.try_ship(p) {
                still.push(p);
            }
        }
        // ship does not recurse into the engine, so nothing new can have
        // landed in the cell meanwhile; restore the survivors in order.
        *ctx.flow.deferred_tx.borrow_mut() = still;
    }
    for peer in 0..ctx.world_size() {
        if ctx.flow.has_pending(peer) {
            flush_peer(ctx, peer);
        }
    }
}

/// Closure-time flow drain, called by the universe after the rank's
/// closure returns (before the quiescence audit, when one runs). Three
/// steps, ordered so every wait terminates for a correct program:
///
/// 1. Flush every owed credit — peers blocked on returns must never wait
///    on *this* rank's further progress.
/// 2. Drive progress until nothing is parked or deferred. Parked sends
///    are a *liveness* obligation (a peer's receive is waiting on the
///    payload), so a stall here past the deadlock limit panics with the
///    leak report and trace ring.
/// 3. Wait for every spent credit to come home. That can only complete
///    once every peer has closed (their last sub-batch returns flush at
///    their own step 1), so the grace timer starts when the whole job
///    has reached closure; credits still missing after the grace are
///    left for the audit to flag — an erroneous program (e.g. a send
///    nobody received) can make them *unsatisfiable*, which must not
///    hang the shutdown.
pub fn quiesce_flow(ctx: &Rc<RankCtx>) -> Result<()> {
    if !ctx.flow.enabled() {
        return Ok(());
    }
    for peer in 0..ctx.world_size() {
        let n = ctx.flow.drain_owed(peer);
        if n > 0 {
            let now = ctx.clock.now_ns();
            ctx.fabric.send(ctx.world_rank, peer, now, PacketKind::CreditReturn { n });
        }
    }
    ctx.fabric.note_rank_closed();
    let start = std::time::Instant::now();
    // Multi-process jobs cannot observe sibling closure, so they get a
    // longer flat grace instead (their caller barriers before quiescing,
    // which bounds the skew in practice).
    let grace = if ctx.fabric.is_multiprocess() {
        Duration::from_secs(10)
    } else {
        Duration::from_secs(2)
    };
    let mut all_closed_at: Option<std::time::Instant> = None;
    loop {
        progress(ctx)?;
        if ctx.flow.quiescent() {
            return Ok(());
        }
        let drained = ctx.flow.deferred_tx.borrow().is_empty()
            && (0..ctx.world_size()).all(|p| !ctx.flow.has_pending(p));
        if drained {
            if all_closed_at.is_none() && ctx.fabric.all_ranks_closed() {
                all_closed_at = Some(std::time::Instant::now());
            }
            if all_closed_at.is_some_and(|t| t.elapsed() > grace) {
                // Only credits are missing and they are not coming: the
                // audit (when enabled) reports the leak.
                return Ok(());
            }
        }
        ctx.fabric.check_abort();
        if start.elapsed() > deadlock_limit() {
            panic!(
                "rank {} flow-control leak at closure: {}\n{}",
                ctx.world_rank,
                ctx.flow.leak_report().join("; "),
                ctx.fabric.trace_report()
            );
        }
        let mut pkts = ctx.scratch.take();
        pkts.clear();
        ctx.fabric.poll_wait(ctx.world_rank, &mut pkts, Duration::from_micros(200));
        let r = pkts.drain(..).try_for_each(|p| handle_packet(ctx, p));
        *ctx.scratch.borrow_mut() = pkts;
        r?;
    }
}

fn process_mailbox(ctx: &RankCtx) -> Result<()> {
    let mut pkts = ctx.scratch.take();
    pkts.clear();
    ctx.fabric.poll(ctx.world_rank, &mut pkts);
    let r = pkts.drain(..).try_for_each(|p| handle_packet(ctx, p));
    *ctx.scratch.borrow_mut() = pkts;
    r
}

fn advance_progressables(ctx: &Rc<RankCtx>) -> Result<()> {
    if ctx.progressables.borrow().is_empty() {
        return Ok(());
    }
    let mut list = ctx.progressables.take();
    let mut err = None;
    let mut remaining = Vec::with_capacity(list.len());
    for p in list.drain(..) {
        match p.advance(ctx) {
            Ok(true) => {}
            Ok(false) => remaining.push(p),
            Err(e) => {
                err = Some(e);
            }
        }
    }
    // Keep anything registered during advance, then the survivors.
    ctx.progressables.borrow_mut().extend(remaining);
    match err {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

/// One non-blocking engine turn: drain the mailbox, handle packets, turn
/// registered composite operations. In chaos mode the turn may first
/// yield the thread (scheduling jitter — free when chaos is off).
pub fn progress(ctx: &Rc<RankCtx>) -> Result<()> {
    ctx.fabric.chaos_tick(ctx.world_rank);
    process_mailbox(ctx)?;
    flush_flow(ctx);
    advance_progressables(ctx)
}

/// Deadline for declaring a deadlock (overridable for tests via
/// `FERROMPI_DEADLOCK_S`).
fn deadlock_limit() -> Duration {
    static LIMIT: std::sync::OnceLock<Duration> = std::sync::OnceLock::new();
    *LIMIT.get_or_init(|| {
        let s = std::env::var("FERROMPI_DEADLOCK_S")
            .ok()
            .and_then(|v| v.parse::<u64>().ok())
            .unwrap_or(60);
        Duration::from_secs(s)
    })
}

/// Drive the engine until `done()` — the blocking wait primitive under
/// every `MPI_Wait`/blocking call. Panics after the deadlock limit with a
/// queue diagnostic (a hung MPI program is a bug in the program).
pub fn wait_for(ctx: &Rc<RankCtx>, mut done: impl FnMut() -> bool) -> Result<()> {
    ctx.counters.waits.set(ctx.counters.waits.get() + 1);
    let start = std::time::Instant::now();
    loop {
        progress(ctx)?;
        if done() {
            return Ok(());
        }
        ctx.fabric.check_abort();
        if start.elapsed() > deadlock_limit() {
            let m = ctx.matcher.borrow();
            let flow = if ctx.flow.enabled() && !ctx.flow.quiescent() {
                format!(", flow: {}", ctx.flow.leak_report().join("; "))
            } else {
                String::new()
            };
            panic!(
                "rank {} deadlocked in wait (posted={}, unexpected={}, sends={}, recvs={}{flow})",
                ctx.world_rank,
                m.posted_len(),
                m.unexpected_len(),
                ctx.sends.borrow().len(),
                ctx.recvs.borrow().len()
            );
        }
        // (Chaos yield jitter is injected once per turn, inside
        // `progress` at the top of the loop.)
        let mut pkts = ctx.scratch.take();
        pkts.clear();
        ctx.fabric
            .poll_wait(ctx.world_rank, &mut pkts, Duration::from_micros(200));
        let r = pkts.drain(..).try_for_each(|p| handle_packet(ctx, p));
        *ctx.scratch.borrow_mut() = pkts;
        r?;
        advance_progressables(ctx)?;
    }
}

/// Is this send token complete? (Completed tokens are removed.)
pub fn take_send_done(ctx: &RankCtx, token: u64) -> bool {
    let mut sends = ctx.sends.borrow_mut();
    if matches!(sends.get(&token), Some(SendState::Done)) {
        sends.remove(&token);
        true
    } else {
        false
    }
}

/// Peek at whether a send is complete without consuming.
pub fn send_done(ctx: &RankCtx, token: u64) -> bool {
    matches!(ctx.sends.borrow().get(&token), Some(SendState::Done) | None)
}

/// If the receive is complete, take its result.
pub fn take_recv_result(ctx: &RankCtx, token: u64) -> Option<Result<Status>> {
    let mut recvs = ctx.recvs.borrow_mut();
    match recvs.get(&token) {
        Some(RecvState { progress: RecvProgress::Pending, .. }) => None,
        Some(_) => {
            let rs = recvs.remove(&token).unwrap();
            match rs.progress {
                RecvProgress::Done(s) => Some(Ok(s)),
                RecvProgress::Failed(e) => Some(Err(e)),
                RecvProgress::Pending => unreachable!(),
            }
        }
        None => Some(Err(mpi_err!(Request, "unknown receive request token {token}"))),
    }
}

/// Non-consuming completion check for receives.
pub fn recv_done(ctx: &RankCtx, token: u64) -> bool {
    !matches!(
        ctx.recvs.borrow().get(&token),
        Some(RecvState { progress: RecvProgress::Pending, .. })
    )
}

// ---------------- probe family ----------------

fn probe_status(_ctx: &RankCtx, msg: &UnexpectedMsg, group: &Group) -> Status {
    Status {
        source: group.rank_of(msg.src).map(|r| r as i32).unwrap_or(-1),
        tag: msg.tag,
        bytes: msg.nbytes(),
        cancelled: false,
    }
}

/// `MPI_Iprobe`: non-blocking envelope check.
pub fn iprobe(
    ctx: &Rc<RankCtx>,
    ctx_id: u32,
    src_world: Option<usize>,
    tag: Option<i32>,
    group: &Group,
) -> Result<Option<Status>> {
    ctx.counters.probes.set(ctx.counters.probes.get() + 1);
    progress(ctx)?;
    let sel = MatchSelector { ctx: ctx_id, src: src_world, tag };
    Ok(ctx.matcher.borrow().peek_unexpected(&sel).map(|m| probe_status(ctx, m, group)))
}

/// `MPI_Probe`: blocking envelope check.
pub fn probe(
    ctx: &Rc<RankCtx>,
    ctx_id: u32,
    src_world: Option<usize>,
    tag: Option<i32>,
    group: &Group,
) -> Result<Status> {
    let sel = MatchSelector { ctx: ctx_id, src: src_world, tag };
    wait_for(ctx, || ctx.matcher.borrow().peek_unexpected(&sel).is_some())?;
    let m = ctx.matcher.borrow();
    Ok(probe_status(ctx, m.peek_unexpected(&sel).unwrap(), group))
}

/// A matched message (`MPI_Mprobe` result): removed from matching, must be
/// received via [`mrecv`].
#[derive(Debug)]
pub struct Message {
    pub(crate) msg: UnexpectedMsg,
}

impl Message {
    pub fn nbytes(&self) -> usize {
        self.msg.nbytes()
    }
}

/// `MPI_Improbe`.
pub fn improbe(
    ctx: &Rc<RankCtx>,
    ctx_id: u32,
    src_world: Option<usize>,
    tag: Option<i32>,
) -> Result<Option<Message>> {
    ctx.counters.probes.set(ctx.counters.probes.get() + 1);
    progress(ctx)?;
    let sel = MatchSelector { ctx: ctx_id, src: src_world, tag };
    Ok(ctx.matcher.borrow_mut().take_unexpected(&sel).map(|msg| Message { msg }))
}

/// `MPI_Mprobe` (blocking).
pub fn mprobe(
    ctx: &Rc<RankCtx>,
    ctx_id: u32,
    src_world: Option<usize>,
    tag: Option<i32>,
) -> Result<Message> {
    let sel = MatchSelector { ctx: ctx_id, src: src_world, tag };
    wait_for(ctx, || ctx.matcher.borrow().peek_unexpected(&sel).is_some())?;
    Ok(Message { msg: ctx.matcher.borrow_mut().take_unexpected(&sel).unwrap() })
}

/// `MPI_Mrecv`: receive a matched message.
pub fn mrecv(
    ctx: &Rc<RankCtx>,
    message: Message,
    buf: RawBufMut,
    count: usize,
    dtype: Datatype,
    group: Group,
) -> Result<Status> {
    dtype.require_committed()?;
    let token = ctx.fresh_token();
    ctx.recvs.borrow_mut().insert(
        token,
        RecvState { buf, count, dtype, group, progress: RecvProgress::Pending },
    );
    match_arrived(ctx, token, message.msg)?;
    wait_for(ctx, || recv_done(ctx, token))?;
    take_recv_result(ctx, token).unwrap()
}

/// `MPI_Cancel` for a posted (still unmatched) receive.
pub fn cancel_recv(ctx: &RankCtx, token: u64) -> Result<bool> {
    let was_pending = ctx.matcher.borrow_mut().cancel_posted(token);
    if was_pending {
        if let Some(rs) = ctx.recvs.borrow_mut().get_mut(&token) {
            rs.progress = RecvProgress::Done(Status {
                source: -1,
                tag: -1,
                bytes: 0,
                cancelled: true,
            });
        }
    }
    Ok(was_pending)
}
