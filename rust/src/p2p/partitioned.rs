//! Partitioned point-to-point communication (MPI-4.0 §4) — the 4.0
//! headline addition. A partitioned send exposes one buffer as
//! `partitions` independently-fillable pieces; the transfer may begin once
//! every partition is marked ready.
//!
//! Implementation: partitions are staged into the send payload as they are
//! declared ready (`pready` packs partition `i` immediately, so the user
//! may refill their buffer); when the last partition arrives the whole
//! message goes out as one ordinary send. The receive side posts one
//! receive for the full buffer; `parrived` reports per-partition arrival
//! (whole-message granularity, a legal implementation since partition
//! arrival may be coarsened).

use super::buffer::{RawBuf, RawBufMut};
use super::engine;
use super::state::{RankCtx, Status};
use crate::comm::Comm;
use crate::datatype::{pack_into, Datatype};
use crate::request::Request;
use crate::{mpi_err, Result};
use std::cell::RefCell;
use std::rc::Rc;

/// `MPI_Psend_init` product.
pub struct PsendRequest {
    ctx: Rc<RankCtx>,
    ctx_id: u32,
    dst: i32,
    tag: i32,
    buf: RawBuf,
    partitions: usize,
    count_per_partition: usize,
    dtype: Datatype,
    comm_resolver: Box<dyn Fn(i32) -> Result<Option<usize>>>,
    state: RefCell<PsendState>,
}

struct PsendState {
    active: bool,
    ready: Vec<bool>,
    staged: Vec<u8>,
    staged_parts: usize,
    inflight: Option<Request>,
}

impl PsendRequest {
    /// `MPI_Psend_init`: `buf` holds `partitions × count` elements.
    pub fn init(
        comm: &Comm,
        buf: &[u8],
        partitions: usize,
        count: usize,
        dtype: &Datatype,
        dst: i32,
        tag: i32,
    ) -> Result<PsendRequest> {
        if partitions == 0 {
            return Err(mpi_err!(Count, "partitioned send needs at least one partition"));
        }
        dtype.require_committed()?;
        let group = comm.group().clone();
        let size = comm.size();
        Ok(PsendRequest {
            ctx: comm.rank_ctx().clone(),
            ctx_id: comm.ctx_p2p(),
            dst,
            tag,
            buf: RawBuf::from_slice(buf),
            partitions,
            count_per_partition: count,
            dtype: dtype.clone(),
            comm_resolver: Box::new(move |d| {
                if d == crate::comm::PROC_NULL {
                    return Ok(None);
                }
                if d < 0 || d as usize >= size {
                    return Err(mpi_err!(Rank, "rank {d} invalid"));
                }
                Ok(Some(group.world_rank(d as usize)?))
            }),
            state: RefCell::new(PsendState {
                active: false,
                ready: vec![false; partitions],
                staged: Vec::new(),
                staged_parts: 0,
                inflight: None,
            }),
        })
    }

    /// `MPI_Start`.
    pub fn start(&self) -> Result<()> {
        let mut st = self.state.borrow_mut();
        if st.active {
            return Err(mpi_err!(Request, "start on active partitioned send"));
        }
        st.active = true;
        st.ready.iter_mut().for_each(|r| *r = false);
        st.staged.clear();
        st.staged
            .resize(self.dtype.size() * self.count_per_partition * self.partitions, 0);
        st.staged_parts = 0;
        st.inflight = None;
        Ok(())
    }

    /// `MPI_Pready`: partition `i`'s data is final; it is packed now.
    pub fn pready(&self, i: usize) -> Result<()> {
        let mut st = self.state.borrow_mut();
        if !st.active {
            return Err(mpi_err!(Request, "pready before start"));
        }
        if i >= self.partitions {
            return Err(mpi_err!(Arg, "partition {i} out of range ({})", self.partitions));
        }
        if st.ready[i] {
            return Err(mpi_err!(Request, "partition {i} already marked ready"));
        }
        st.ready[i] = true;
        st.staged_parts += 1;
        // Pack partition i from the user buffer straight into its slot of
        // the staging buffer (no intermediate allocation or copy).
        let esz = self.dtype.extent() as usize;
        let wire_sz = self.dtype.size() * self.count_per_partition;
        let full = unsafe { self.buf.as_slice() };
        let lo = i * self.count_per_partition * esz;
        let hi = (lo + self.count_per_partition * esz).min(full.len());
        let off = i * wire_sz;
        pack_into(
            self.dtype.map(),
            &full[lo..hi],
            self.count_per_partition,
            &mut st.staged[off..off + wire_sz],
        )?;
        // Two-hop path: this staging memcpy is a CPU copy regardless of
        // contiguity (the later staged→wire move is the DMA-modeled one),
        // so it always charges the copy counter.
        self.ctx.fabric.pool.count_copied(wire_sz);

        if st.staged_parts == self.partitions {
            // All ready: ship as one message.
            let byte = Datatype::primitive(crate::datatype::Primitive::Byte);
            match (self.comm_resolver)(self.dst)? {
                None => {
                    st.inflight = Some(Request::ready(self.ctx.clone(), Status::empty()));
                }
                Some(dst_world) => {
                    let token = engine::start_send(
                        &self.ctx,
                        super::engine::SendParams {
                            ctx_id: self.ctx_id,
                            dst_world,
                            tag: self.tag,
                            buf: &st.staged,
                            count: st.staged.len(),
                            dtype: &byte,
                            mode: super::engine::SendMode::Standard,
                            // The staging buffer is stable until `wait`
                            // deactivates this request, and wait only
                            // returns once the send completed (i.e. after
                            // any CTS-time packing read it).
                            staging: super::engine::RndvStaging::Deferred,
                        },
                    )?;
                    st.inflight = Some(Request::from_send(self.ctx.clone(), token));
                }
            }
        }
        Ok(())
    }

    /// `MPI_Pready_range`.
    pub fn pready_range(&self, lo: usize, hi: usize) -> Result<()> {
        for i in lo..=hi {
            self.pready(i)?;
        }
        Ok(())
    }

    /// `MPI_Wait` on the partitioned send; deactivates for reuse.
    pub fn wait(&self) -> Result<Status> {
        {
            let st = self.state.borrow();
            if !st.active {
                return Err(mpi_err!(Request, "wait on inactive partitioned send"));
            }
            if st.staged_parts != self.partitions {
                return Err(mpi_err!(
                    Pending,
                    "wait with only {}/{} partitions ready would deadlock",
                    st.staged_parts,
                    self.partitions
                ));
            }
        }
        let req = self.state.borrow_mut().inflight.take().expect("inflight set");
        let s = match req.wait() {
            Ok(s) => s,
            Err(e) => {
                // The staging buffer may be freed before a late CTS: park
                // the payload as staged bytes while it is still live.
                req.detach_buffers();
                self.state.borrow_mut().active = false;
                return Err(e);
            }
        };
        self.state.borrow_mut().active = false;
        Ok(s)
    }
}

impl Drop for PsendRequest {
    /// The in-flight send may hold only the *address* of the staging
    /// buffer (deferred rendezvous packing), so the buffer must outlive
    /// the transfer: block for completion before the staging buffer is
    /// freed. Skipped while unwinding, like `PersistentRequest`.
    fn drop(&mut self) {
        if std::thread::panicking() {
            return;
        }
        if let Some(req) = self.state.borrow_mut().inflight.take() {
            if req.wait().is_err() {
                req.detach_buffers();
            }
        }
    }
}

/// `MPI_Precv_init` product.
pub struct PrecvRequest {
    partitions: usize,
    comm_ctx: Rc<RankCtx>,
    spec: RefCell<PrecvState>,
}

struct PrecvState {
    active: Option<Request>,
    done: bool,
}

impl PrecvRequest {
    #[allow(clippy::too_many_arguments)]
    pub fn init(
        comm: &Comm,
        buf: &mut [u8],
        partitions: usize,
        count: usize,
        dtype: &Datatype,
        src: i32,
        tag: i32,
    ) -> Result<(PrecvRequest, PrecvStart)> {
        if partitions == 0 {
            return Err(mpi_err!(Count, "partitioned recv needs at least one partition"));
        }
        dtype.require_committed()?;
        Ok((
            PrecvRequest {
                partitions,
                comm_ctx: comm.rank_ctx().clone(),
                spec: RefCell::new(PrecvState { active: None, done: false }),
            },
            PrecvStart {
                buf: RawBufMut::from_slice(buf),
                total_count: partitions * count,
                dtype: dtype.clone(),
                src,
                tag,
            },
        ))
    }

    /// `MPI_Start`: posts the underlying receive.
    pub fn start(&self, comm: &Comm, s: &PrecvStart) -> Result<()> {
        let mut st = self.spec.borrow_mut();
        if st.active.is_some() {
            return Err(mpi_err!(Request, "start on active partitioned recv"));
        }
        let buf = unsafe { s.buf.as_slice_mut() };
        let req = comm.irecv(buf, s.total_count, &s.dtype, s.src, s.tag)?;
        st.active = Some(req);
        st.done = false;
        Ok(())
    }

    /// `MPI_Parrived`: has partition `i` arrived? (Whole-message
    /// granularity: flips for all partitions at once.)
    pub fn parrived(&self, i: usize) -> Result<bool> {
        if i >= self.partitions {
            return Err(mpi_err!(Arg, "partition {i} out of range"));
        }
        let mut st = self.spec.borrow_mut();
        if st.done {
            return Ok(true);
        }
        engine::progress(&self.comm_ctx)?;
        if let Some(req) = &st.active {
            if let Some(_status) = req.test()? {
                st.done = true;
                return Ok(true);
            }
        }
        Ok(false)
    }

    /// `MPI_Wait`: completes the whole partitioned receive.
    pub fn wait(&self) -> Result<Status> {
        let req = {
            let mut st = self.spec.borrow_mut();
            st.active
                .take()
                .ok_or_else(|| mpi_err!(Request, "wait on inactive partitioned recv"))?
        };
        let s = req.wait()?;
        self.spec.borrow_mut().done = true;
        Ok(s)
    }
}

impl Drop for PrecvRequest {
    /// A posted partitioned receive writes through a raw pointer into the
    /// user's buffer (captured at init); dropping the request while it is
    /// active must block for completion so the engine never delivers into
    /// freed memory — the same lifetime discipline as `PsendRequest` and
    /// `PersistentRequest`. Skipped while unwinding.
    fn drop(&mut self) {
        if std::thread::panicking() {
            return;
        }
        if let Some(req) = self.spec.borrow_mut().active.take() {
            if req.wait().is_err() {
                // Rescue wait failed: drop the engine's pointer into the
                // user buffer before the buffer itself dies.
                req.detach_buffers();
            }
        }
    }
}

/// Captured start parameters for a partitioned receive (kept separate so
/// the request object itself stays reusable across start cycles).
pub struct PrecvStart {
    buf: RawBufMut,
    total_count: usize,
    dtype: Datatype,
    src: i32,
    tag: i32,
}
