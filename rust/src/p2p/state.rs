//! Per-rank runtime state (`RankCtx`) and completion bookkeeping.

use super::buffer::{RawBuf, RawBufMut};
use super::matcher::Matcher;
use crate::datatype::Datatype;
use crate::group::Group;
use crate::transport::fabric::PreparedSend;
use crate::transport::{Fabric, FlowConfig, Packet, VClock, WireBytes};
use crate::{MpiError, Result};
use std::cell::{Cell, RefCell};
use std::collections::{HashMap, VecDeque};
use std::rc::Rc;
use std::sync::Arc;

/// Receive-side completion record (`MPI_Status` analog). `source` and
/// `tag` are in the matched communicator's group terms.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Status {
    pub source: i32,
    pub tag: i32,
    /// Wire bytes received (drives `MPI_Get_count`).
    pub bytes: usize,
    pub cancelled: bool,
}

impl Status {
    /// An empty status (completed sends, PROC_NULL ops).
    pub fn empty() -> Status {
        Status { source: -1, tag: -1, bytes: 0, cancelled: false }
    }

    /// `MPI_Get_count`: number of whole elements received, `None` =
    /// `MPI_UNDEFINED` (not a whole number of elements).
    pub fn get_count(&self, dtype: &Datatype) -> Option<usize> {
        let sz = dtype.size();
        if sz == 0 {
            return Some(0);
        }
        if self.bytes % sz == 0 {
            Some(self.bytes / sz)
        } else {
            None
        }
    }
}

/// State of an in-flight send.
#[derive(Debug)]
pub enum SendState {
    /// Rendezvous, staged: payload packed at post time into a pooled wire
    /// buffer and parked here until the CTS (internal senders whose source
    /// range is mutable before completion, e.g. the collective arena).
    AwaitCts { staged: WireBytes },
    /// Rendezvous, zero-copy: packing is deferred until the CTS arrives —
    /// only the user buffer's address is parked. Sound because the MPI
    /// contract forbids touching a send buffer before the operation
    /// completes, and completion is at CTS processing (after packing).
    AwaitCtsDeferred { buf: RawBuf, count: usize, dtype: Datatype },
    /// Eager synchronous send: waiting for the receiver's match ack.
    AwaitAck,
    Done,
}

/// State of an in-flight receive.
#[derive(Debug)]
pub enum RecvProgress {
    /// Posted (or matched an RTS and awaiting RData).
    Pending,
    Done(Status),
    Failed(MpiError),
}

/// A pending receive's full record.
#[derive(Debug)]
pub struct RecvState {
    pub buf: RawBufMut,
    pub count: usize,
    pub dtype: Datatype,
    /// Group of the communicator, for world→group source translation.
    pub group: Group,
    pub progress: RecvProgress,
}

/// Origin-side progress of one one-sided (RMA) operation: inserted as
/// `Pending` when the `Rma*` packet is injected, flipped to `Done` by the
/// target's `RmaAck`/`RmaGetResp` reply. The payload is the response data
/// (a shared view of a pooled wire buffer; empty for put/accumulate acks).
#[derive(Debug)]
pub enum RmaProgress {
    Pending,
    Done(WireBytes),
}

/// Origin-side progress of one asynchronous MPI-IO operation: inserted as
/// `Pending` when the `Io*` request packet is injected, flipped to `Done`
/// by the file server's `IoDone`/`IoData` reply. For reads the payload is
/// the (possibly short) data that came back; for writes and metadata ops
/// it is empty and `value` carries the scalar result.
#[derive(Debug)]
pub enum IoProgress {
    Pending,
    Done { data: WireBytes, value: u64 },
    Failed(MpiError),
}

/// Rank-local memory of one RMA window — the target side of one-sided
/// operations. The exposed segment is written **only** by the owning
/// rank's engine thread as `Rma*` packets are processed (and by the owner
/// itself through `with_local`), which is what makes RMA atomics
/// (accumulate, fetch-and-op, compare-and-swap) linearizable without any
/// cross-rank locking of the data.
#[derive(Debug)]
pub struct WindowMem {
    pub seg: RefCell<Vec<u8>>,
}

/// Buffered-send pool (`MPI_Buffer_attach`). We account capacity the way
/// the standard requires (bsend fails with `MPI_ERR_BUFFER` when the
/// attached buffer cannot hold the packed message + overhead).
#[derive(Debug, Default)]
pub struct BsendPool {
    pub capacity: usize,
    pub in_use: usize,
}

/// `MPI_BSEND_OVERHEAD` analog.
pub const BSEND_OVERHEAD: usize = 64;

/// Anything that makes progress when the engine turns over: nonblocking
/// collectives, collective IO, generalized requests. `advance` must not
/// block and must not recursively call the progress engine.
pub trait Progressable {
    /// Returns `Ok(true)` when complete (it is then dropped from the
    /// progress list).
    fn advance(&self, ctx: &Rc<RankCtx>) -> Result<bool>;
}

/// This rank's eager flow-control ledger (see `docs/FLOWCONTROL.md`).
/// Thread-confined like the rest of [`RankCtx`]. Both halves of the
/// protocol live here: the *sender* side (credits available toward each
/// peer, parked sends waiting for liquidity) and the *receiver* side
/// (credits owed back to each peer, batched into `CreditReturn` packets).
#[derive(Debug)]
pub struct FlowState {
    pub cfg: FlowConfig,
    /// Credits this rank may spend toward each peer. Starts at (and must
    /// return to, at quiescence) `cfg.window` per peer.
    avail: Vec<Cell<usize>>,
    /// Prepared packets parked per peer, strictly FIFO: once anything is
    /// parked for a peer, every later matching-domain packet to that peer
    /// (including demoted RTS, which cost no credit) queues behind it —
    /// shipping around the queue would break non-overtaking.
    pending: Vec<RefCell<VecDeque<PreparedSend>>>,
    /// How many entries of each peer's pending queue are payload-bearing
    /// eager packets (the demotion threshold counts these, not the
    /// header-only RTS riding along for ordering).
    parked_payloads: Vec<Cell<usize>>,
    /// Receiver side: credits owed to each peer, flushed at
    /// `cfg.return_batch()` and at closure end.
    owed: Vec<Cell<u32>>,
    /// Payload packets originated *inside* the packet handler (rendezvous
    /// RData, RMA get responses) that hit mailbox backpressure. They are
    /// token-addressed and order-free, so they sit here and retry each
    /// progress turn instead of recursing into the engine.
    pub deferred_tx: RefCell<Vec<PreparedSend>>,
}

impl FlowState {
    pub fn new(cfg: FlowConfig, nranks: usize) -> FlowState {
        FlowState {
            cfg,
            avail: (0..nranks).map(|_| Cell::new(cfg.window)).collect(),
            pending: (0..nranks).map(|_| RefCell::new(VecDeque::new())).collect(),
            parked_payloads: (0..nranks).map(|_| Cell::new(0)).collect(),
            owed: (0..nranks).map(|_| Cell::new(0)).collect(),
            deferred_tx: RefCell::new(Vec::new()),
        }
    }

    pub fn enabled(&self) -> bool {
        self.cfg.enabled()
    }

    pub fn avail(&self, peer: usize) -> usize {
        self.avail[peer].get()
    }

    /// Consume one credit toward `peer`; `false` when out.
    pub fn take_credit(&self, peer: usize) -> bool {
        let a = self.avail[peer].get();
        if a == 0 {
            return false;
        }
        self.avail[peer].set(a - 1);
        true
    }

    pub fn give_credit(&self, peer: usize) {
        self.avail[peer].set(self.avail[peer].get() + 1);
    }

    /// Credit the sender ledger with `n` returned credits from `peer`.
    pub fn returned(&self, peer: usize, n: u32) {
        self.avail[peer].set(self.avail[peer].get() + n as usize);
    }

    pub fn pending(&self, peer: usize) -> &RefCell<VecDeque<PreparedSend>> {
        &self.pending[peer]
    }

    pub fn has_pending(&self, peer: usize) -> bool {
        !self.pending[peer].borrow().is_empty()
    }

    /// Payload-bearing entries parked for `peer` (the demotion threshold).
    pub fn parked_payloads(&self, peer: usize) -> usize {
        self.parked_payloads[peer].get()
    }

    pub fn note_parked_payload(&self, peer: usize, delta: isize) {
        let v = self.parked_payloads[peer].get() as isize + delta;
        debug_assert!(v >= 0);
        self.parked_payloads[peer].set(v.max(0) as usize);
    }

    /// Receiver side: one more eager message from `peer` delivered.
    /// Returns `Some(n)` when a batch is due to go back on the wire.
    pub fn accrue_owed(&self, peer: usize) -> Option<u32> {
        let o = self.owed[peer].get() + 1;
        if o >= self.cfg.return_batch() {
            self.owed[peer].set(0);
            Some(o)
        } else {
            self.owed[peer].set(o);
            None
        }
    }

    /// Take everything still owed to `peer` (closure-end flush).
    pub fn drain_owed(&self, peer: usize) -> u32 {
        self.owed[peer].replace(0)
    }

    pub fn owed(&self, peer: usize) -> u32 {
        self.owed[peer].get()
    }

    /// Sender-side quiescence: every credit home, nothing parked or
    /// deferred. (`owed` is receiver-side and flushed separately.)
    pub fn quiescent(&self) -> bool {
        self.avail.iter().all(|a| a.get() == self.cfg.window)
            && self.pending.iter().all(|p| p.borrow().is_empty())
            && self.deferred_tx.borrow().is_empty()
    }

    /// Human-readable leak description for the quiescence audit.
    pub fn leak_report(&self) -> Vec<String> {
        let mut out = Vec::new();
        for (peer, a) in self.avail.iter().enumerate() {
            if a.get() != self.cfg.window {
                out.push(format!(
                    "credits toward r{peer}: {}/{} home",
                    a.get(),
                    self.cfg.window
                ));
            }
        }
        for (peer, q) in self.pending.iter().enumerate() {
            let q = q.borrow();
            if !q.is_empty() {
                out.push(format!("{} send(s) still parked for r{peer}", q.len()));
            }
        }
        for (peer, o) in self.owed.iter().enumerate() {
            if o.get() != 0 {
                out.push(format!("{} credit(s) still owed to r{peer}", o.get()));
            }
        }
        let d = self.deferred_tx.borrow().len();
        if d != 0 {
            out.push(format!("{d} deferred reply packet(s) never shipped"));
        }
        out
    }
}

/// Per-rank software counters exported as tool pvars.
#[derive(Debug, Default)]
pub struct RankCounters {
    pub sends_started: Cell<u64>,
    pub recvs_posted: Cell<u64>,
    pub messages_matched: Cell<u64>,
    pub probes: Cell<u64>,
    pub collectives_started: Cell<u64>,
    pub waits: Cell<u64>,
}

/// All rank-local MPI state. Confined to the rank's own thread.
pub struct RankCtx {
    pub world_rank: usize,
    pub fabric: Arc<Fabric>,
    pub clock: VClock,
    pub matcher: RefCell<Matcher>,
    pub sends: RefCell<HashMap<u64, SendState>>,
    pub recvs: RefCell<HashMap<u64, RecvState>>,
    pub counters: RankCounters,
    pub(crate) next_token: Cell<u64>,
    /// Next context id this rank would propose for a new communicator.
    pub(crate) next_ctx: Cell<u32>,
    /// Per-collective-context operation sequence numbers (collective calls
    /// are ordered per communicator, so these agree across ranks).
    pub(crate) coll_seq: RefCell<HashMap<u32, u64>>,
    pub(crate) bsend: RefCell<BsendPool>,
    /// Matched-but-undelivered rendezvous receives: token → (src, tag).
    pub(crate) pending_rndv: RefCell<HashMap<u64, (usize, i32)>>,
    /// In-flight one-sided operations this rank originated: token →
    /// progress (completed by the target's `RmaAck`/`RmaGetResp`).
    pub(crate) rma: RefCell<HashMap<u64, RmaProgress>>,
    /// In-flight MPI-IO operations this rank originated: token → progress
    /// (completed by the file server's `IoDone`/`IoData` reply).
    pub(crate) io: RefCell<HashMap<u64, IoProgress>>,
    /// RMA windows whose local segment this rank exposes: window id →
    /// memory. Registered at `MPI_Win_allocate`, retired at `MPI_Win_free`.
    pub(crate) windows: RefCell<HashMap<u32, Rc<WindowMem>>>,
    /// Nonblocking composite operations that need turning.
    pub(crate) progressables: RefCell<Vec<Rc<dyn Progressable>>>,
    /// Scratch packet vec reused across progress calls (hot-path
    /// allocation avoidance).
    pub(crate) scratch: RefCell<Vec<Packet>>,
    /// Eager flow-control ledger (credits, parked sends, owed returns).
    pub(crate) flow: FlowState,
}

impl RankCtx {
    pub fn new(world_rank: usize, fabric: Arc<Fabric>) -> Rc<RankCtx> {
        let epoch = fabric.epoch;
        let flow = FlowState::new(fabric.flow, fabric.nranks());
        Rc::new(RankCtx {
            world_rank,
            fabric,
            clock: VClock::new(epoch),
            matcher: RefCell::new(Matcher::new()),
            sends: RefCell::new(HashMap::new()),
            recvs: RefCell::new(HashMap::new()),
            counters: RankCounters::default(),
            next_token: Cell::new(1),
            // ctx 0/1 are MPI_COMM_WORLD's p2p/collective contexts; user
            // communicators allocate from 16 upward (even=p2p, odd=coll).
            next_ctx: Cell::new(16),
            coll_seq: RefCell::new(HashMap::new()),
            bsend: RefCell::new(BsendPool::default()),
            pending_rndv: RefCell::new(HashMap::new()),
            rma: RefCell::new(HashMap::new()),
            io: RefCell::new(HashMap::new()),
            windows: RefCell::new(HashMap::new()),
            progressables: RefCell::new(Vec::new()),
            scratch: RefCell::new(Vec::new()),
            flow,
        })
    }

    pub fn fresh_token(&self) -> u64 {
        let t = self.next_token.get();
        self.next_token.set(t + 1);
        t
    }

    /// Number of ranks in the world.
    pub fn world_size(&self) -> usize {
        self.fabric.nranks()
    }

    /// Next sequence number for a collective on context `ctx` (identical
    /// across ranks because collective calls are ordered per communicator).
    pub fn next_coll_seq(&self, ctx: u32) -> u64 {
        let mut m = self.coll_seq.borrow_mut();
        let e = m.entry(ctx).or_insert(0);
        let v = *e;
        *e += 1;
        v
    }

    /// Register a nonblocking composite op for progression.
    pub fn register_progressable(&self, p: Rc<dyn Progressable>) {
        self.progressables.borrow_mut().push(p);
    }

    /// `MPI_Buffer_attach` / `detach`.
    pub fn buffer_attach(&self, capacity: usize) {
        let mut b = self.bsend.borrow_mut();
        b.capacity = capacity;
        b.in_use = 0;
    }

    pub fn buffer_detach(&self) -> usize {
        let mut b = self.bsend.borrow_mut();
        let c = b.capacity;
        b.capacity = 0;
        b.in_use = 0;
        c
    }
}

impl std::fmt::Debug for RankCtx {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("RankCtx")
            .field("world_rank", &self.world_rank)
            .field("world_size", &self.world_size())
            .finish_non_exhaustive()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::{NetworkModel, NodeMap};

    fn ctx() -> Rc<RankCtx> {
        let fabric = Arc::new(Fabric::new(NodeMap::new(1, 2), NetworkModel::zero()));
        RankCtx::new(0, fabric)
    }

    #[test]
    fn tokens_unique() {
        let c = ctx();
        let a = c.fresh_token();
        let b = c.fresh_token();
        assert_ne!(a, b);
    }

    #[test]
    fn coll_seq_per_context() {
        let c = ctx();
        assert_eq!(c.next_coll_seq(1), 0);
        assert_eq!(c.next_coll_seq(1), 1);
        assert_eq!(c.next_coll_seq(3), 0);
        assert_eq!(c.next_coll_seq(1), 2);
    }

    #[test]
    fn status_get_count() {
        let s = Status { source: 0, tag: 0, bytes: 12, cancelled: false };
        let i32t = Datatype::primitive(crate::datatype::Primitive::I32);
        let f64t = Datatype::primitive(crate::datatype::Primitive::F64);
        assert_eq!(s.get_count(&i32t), Some(3));
        assert_eq!(s.get_count(&f64t), None); // 12 % 8 != 0 → MPI_UNDEFINED
    }

    #[test]
    fn flow_ledger_credits_and_owed_batches() {
        let f = FlowState::new(FlowConfig { window: 4, pending_cap: 2, mailbox_cap: 0 }, 2);
        assert!(f.enabled());
        assert!(f.quiescent());
        assert_eq!(f.avail(1), 4);
        for _ in 0..4 {
            assert!(f.take_credit(1));
        }
        assert!(!f.take_credit(1), "window exhausted");
        assert!(!f.quiescent());
        assert!(f.leak_report().iter().any(|l| l.contains("0/4 home")));
        f.returned(1, 3);
        f.give_credit(1);
        assert!(f.quiescent());
        // Receiver side: batch fires at window/2 = 2 deliveries.
        assert_eq!(f.accrue_owed(0), None);
        assert_eq!(f.owed(0), 1);
        assert_eq!(f.accrue_owed(0), Some(2));
        assert_eq!(f.owed(0), 0);
        assert_eq!(f.accrue_owed(0), None);
        assert_eq!(f.drain_owed(0), 1);
        assert_eq!(f.drain_owed(0), 0);
    }

    #[test]
    fn rank_ctx_flow_matches_fabric_plan() {
        let c = ctx();
        assert_eq!(c.flow.cfg, c.fabric.flow);
        assert_eq!(c.flow.avail(1), c.fabric.flow.window);
    }

    #[test]
    fn bsend_pool_attach_detach() {
        let c = ctx();
        c.buffer_attach(1024);
        assert_eq!(c.bsend.borrow().capacity, 1024);
        assert_eq!(c.buffer_detach(), 1024);
        assert_eq!(c.bsend.borrow().capacity, 0);
    }
}
