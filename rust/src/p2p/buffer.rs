//! Lifetime-erased buffer handles.
//!
//! The C MPI interface traffics in `void*` + count; requests capture the
//! pointer and the standard forbids touching the buffer until completion.
//! These wrappers reproduce that contract explicitly: constructing one from
//! a slice erases the lifetime, and the unsafe `as_slice` accessors are
//! only called by the owning rank's own progress engine (single-threaded
//! access by construction).

/// Borrowed send buffer (const). Eager sends pack immediately and drop
/// it; rendezvous sends with deferred staging park it until the CTS
/// arrives, relying on the MPI contract that the send buffer stays live
/// and untouched until the operation completes.
#[derive(Debug, Clone, Copy)]
pub struct RawBuf {
    ptr: *const u8,
    len: usize,
}

impl RawBuf {
    pub fn from_slice(s: &[u8]) -> RawBuf {
        RawBuf { ptr: s.as_ptr(), len: s.len() }
    }

    /// # Safety
    /// The original buffer must still be live and not mutably aliased.
    pub unsafe fn as_slice<'a>(&self) -> &'a [u8] {
        if self.len == 0 {
            &[]
        } else {
            std::slice::from_raw_parts(self.ptr, self.len)
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

/// Captured receive buffer. Held by a pending receive until completion.
#[derive(Debug, Clone, Copy)]
pub struct RawBufMut {
    ptr: *mut u8,
    len: usize,
}

impl RawBufMut {
    /// Capture a mutable slice. The *caller* promises (per the MPI
    /// contract) not to access the region until the receive completes.
    pub fn from_slice(s: &mut [u8]) -> RawBufMut {
        RawBufMut { ptr: s.as_mut_ptr(), len: s.len() }
    }

    /// # Safety
    /// Must only be called on the owning rank's thread while the original
    /// allocation is live and the MPI completion contract holds.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn as_slice_mut<'a>(&self) -> &'a mut [u8] {
        if self.len == 0 {
            &mut []
        } else {
            std::slice::from_raw_parts_mut(self.ptr, self.len)
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_const() {
        let data = [1u8, 2, 3];
        let b = RawBuf::from_slice(&data);
        assert_eq!(b.len(), 3);
        assert_eq!(unsafe { b.as_slice() }, &[1, 2, 3]);
    }

    #[test]
    fn roundtrip_mut() {
        let mut data = [0u8; 4];
        let b = RawBufMut::from_slice(&mut data);
        unsafe { b.as_slice_mut()[2] = 9 };
        assert_eq!(data, [0, 0, 9, 0]);
    }

    #[test]
    fn empty_buffers() {
        let b = RawBuf::from_slice(&[]);
        assert!(b.is_empty());
        assert_eq!(unsafe { b.as_slice() }.len(), 0);
        let mut v: Vec<u8> = vec![];
        let m = RawBufMut::from_slice(&mut v);
        assert!(m.is_empty());
    }
}
