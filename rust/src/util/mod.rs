//! Support utilities built in-repo (this environment has no network access,
//! so `rand`, `clap`, `criterion` and `proptest` are replaced by the small
//! purpose-built implementations below — see DESIGN.md §8).

pub mod alloc_count;
pub mod hash;
pub mod rng;
pub mod stats;
pub mod timing;
pub mod cli;
pub mod prop;
pub mod microbench;
pub mod table;
