//! A counting global allocator for the benches: wraps the system
//! allocator and tallies every allocation (count and bytes), so a bench
//! can assert "the steady-state message path allocates nothing" instead
//! of inferring it from timings.
//!
//! Usage (in a bench binary):
//!
//! ```ignore
//! #[global_allocator]
//! static ALLOC: ferrompi::util::alloc_count::CountingAlloc =
//!     ferrompi::util::alloc_count::CountingAlloc;
//! ```
//!
//! Counters are process-global and monotone; measure deltas around the
//! region of interest. `realloc` counts as one allocation (it may move).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOCATIONS: AtomicU64 = AtomicU64::new(0);
static ALLOCATED_BYTES: AtomicU64 = AtomicU64::new(0);

/// The counting wrapper around [`System`].
pub struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        ALLOCATED_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        ALLOCATED_BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

/// Total allocations since process start (monotone).
pub fn allocations() -> u64 {
    ALLOCATIONS.load(Ordering::Relaxed)
}

/// Total bytes requested since process start (monotone; not live bytes).
pub fn allocated_bytes() -> u64 {
    ALLOCATED_BYTES.load(Ordering::Relaxed)
}
