//! FNV-1a — the tree's one tiny, stable, dependency-free hash, shared by
//! everything that needs a reproducible 64-bit digest (session-level
//! context-id derivation, the chaos harness's payload digests). Stability
//! matters: these values cross ranks and runs, so the algorithm lives in
//! exactly one place.

/// Incremental FNV-1a hasher.
#[derive(Debug, Clone)]
pub struct Fnv1a(u64);

impl Fnv1a {
    pub fn new() -> Fnv1a {
        Fnv1a(0xcbf2_9ce4_8422_2325)
    }

    #[inline]
    pub fn eat(&mut self, b: u8) {
        self.0 ^= b as u64;
        self.0 = self.0.wrapping_mul(0x0000_0100_0000_01B3);
    }

    pub fn eat_bytes(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.eat(b);
        }
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

impl Default for Fnv1a {
    fn default() -> Fnv1a {
        Fnv1a::new()
    }
}

/// One-shot FNV-1a of a byte slice.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = Fnv1a::new();
    h.eat_bytes(bytes);
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // Canonical FNV-1a 64-bit test vectors.
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn incremental_matches_one_shot() {
        let mut h = Fnv1a::new();
        h.eat_bytes(b"foo");
        h.eat_bytes(b"bar");
        assert_eq!(h.finish(), fnv1a(b"foobar"));
    }
}
