//! CSV and aligned-markdown table writers for benchmark reports
//! (the Figure 1 regeneration emits both).

use std::io::Write;
use std::path::Path;

/// An in-memory table with a header row.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn push(&mut self, row: Vec<String>) {
        assert_eq!(row.len(), self.header.len(), "row width mismatch");
        self.rows.push(row);
    }

    /// Render as CSV (RFC-4180-ish; quotes fields containing separators).
    pub fn to_csv(&self) -> String {
        let mut s = String::new();
        let esc = |f: &str| {
            if f.contains(',') || f.contains('"') || f.contains('\n') {
                format!("\"{}\"", f.replace('"', "\"\""))
            } else {
                f.to_string()
            }
        };
        s.push_str(&self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
        s.push('\n');
        for r in &self.rows {
            s.push_str(&r.iter().map(|f| esc(f)).collect::<Vec<_>>().join(","));
            s.push('\n');
        }
        s
    }

    /// Render as an aligned GitHub-flavored markdown table.
    pub fn to_markdown(&self) -> String {
        let ncol = self.header.len();
        let mut widths = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            widths[i] = widths[i].max(h.len());
        }
        for r in &self.rows {
            for (i, f) in r.iter().enumerate() {
                widths[i] = widths[i].max(f.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            let mut line = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                line.push_str(&format!(" {:<w$} |", c, w = widths[i]));
            }
            line.push('\n');
            line
        };
        let mut s = fmt_row(&self.header);
        s.push('|');
        for w in &widths {
            s.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        s.push('\n');
        for r in &self.rows {
            s.push_str(&fmt_row(r));
        }
        s
    }

    pub fn write_csv(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_csv().as_bytes())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_escapes() {
        let mut t = Table::new(&["a", "b"]);
        t.push(vec!["x,y".into(), "pla\"in".into()]);
        let csv = t.to_csv();
        assert_eq!(csv, "a,b\n\"x,y\",\"pla\"\"in\"\n");
    }

    #[test]
    fn markdown_aligns() {
        let mut t = Table::new(&["op", "ns"]);
        t.push(vec!["barrier".into(), "120".into()]);
        t.push(vec!["bcast".into(), "7".into()]);
        let md = t.to_markdown();
        assert!(md.contains("| op      | ns  |"), "{md}");
        assert!(md.lines().count() == 4);
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn width_checked() {
        let mut t = Table::new(&["a"]);
        t.push(vec!["1".into(), "2".into()]);
    }
}
