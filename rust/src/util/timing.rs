//! Wall-clock helpers (`MPI_Wtime` analog) and a simulated-time clock used
//! by the fabric's network model.
//!
//! The fabric charges α–β costs in *virtual* nanoseconds accumulated per
//! rank (see [`crate::transport::netmodel`]); real wall time is used for the
//! measurement loops themselves, exactly like mpiBench's `MPI_Wtime` deltas.

use std::time::Instant;

/// Process-global epoch so `wtime()` is comparable across rank threads.
static EPOCH: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

/// `MPI_Wtime` analog: seconds since a process-global epoch.
pub fn wtime() -> f64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_secs_f64()
}

/// `MPI_Wtick` analog: the resolution of `wtime` (Instant is nanosecond
/// resolution on Linux).
pub fn wtick() -> f64 {
    1e-9
}

/// A simple stopwatch for benchmark loops.
#[derive(Debug, Clone, Copy)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_ns(&self) -> u64 {
        self.start.elapsed().as_nanos() as u64
    }

    pub fn restart(&mut self) -> f64 {
        let e = self.elapsed_s();
        self.start = Instant::now();
        e
    }
}

/// Format a nanosecond quantity human-readably (for reports).
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Format a byte count with binary prefixes (for message-length axes).
pub fn fmt_bytes(b: usize) -> String {
    if b < 1024 {
        format!("{b} B")
    } else if b < 1024 * 1024 {
        format!("{} KiB", b / 1024)
    } else {
        format!("{} MiB", b / (1024 * 1024))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wtime_monotonic() {
        let a = wtime();
        let b = wtime();
        assert!(b >= a);
        assert!(wtick() > 0.0);
    }

    #[test]
    fn stopwatch_measures() {
        let sw = Stopwatch::start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(sw.elapsed_s() >= 0.004);
        assert!(sw.elapsed_ns() >= 4_000_000);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_ns(512.0), "512 ns");
        assert_eq!(fmt_ns(2_500.0), "2.50 us");
        assert_eq!(fmt_ns(3_000_000.0), "3.00 ms");
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2 KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3 MiB");
    }
}
