//! A minimal command-line argument parser (no `clap` in this offline
//! environment). Supports subcommands, `--flag`, `--key value`,
//! `--key=value` and positional arguments, with generated `--help` text.

use std::collections::BTreeMap;

/// Declarative option spec for help generation.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
    pub help: &'static str,
}

/// Parsed arguments: flags, key→value options, positionals.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub flags: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse raw argv (without the program/subcommand name) against a spec.
    /// Unknown `--options` are errors so typos fail loudly.
    pub fn parse(raw: &[String], spec: &[OptSpec]) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = raw.iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let s = spec
                    .iter()
                    .find(|s| s.name == key)
                    .ok_or_else(|| format!("unknown option --{key}"))?;
                if s.takes_value {
                    let v = match inline_val {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| format!("--{key} requires a value"))?
                            .clone(),
                    };
                    out.options.insert(key, v);
                } else {
                    if inline_val.is_some() {
                        return Err(format!("--{key} takes no value"));
                    }
                    out.flags.push(key);
                }
            } else {
                out.positional.push(a.clone());
            }
        }
        for s in spec {
            if let (true, Some(d)) = (s.takes_value, s.default) {
                out.options.entry(s.name.to_string()).or_insert_with(|| d.to_string());
            }
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_parsed<T: std::str::FromStr>(&self, name: &str) -> Result<T, String>
    where
        T::Err: std::fmt::Display,
    {
        let v = self.get(name).ok_or_else(|| format!("missing --{name}"))?;
        v.parse::<T>().map_err(|e| format!("--{name}={v}: {e}"))
    }

    /// Parse a comma-separated list of T, e.g. `--nodes 1,2,4,8,16`.
    pub fn get_list<T: std::str::FromStr>(&self, name: &str) -> Result<Vec<T>, String>
    where
        T::Err: std::fmt::Display,
    {
        let v = self.get(name).ok_or_else(|| format!("missing --{name}"))?;
        v.split(',')
            .map(|s| s.trim().parse::<T>().map_err(|e| format!("--{name}: '{s}': {e}")))
            .collect()
    }
}

/// Render help text for a subcommand.
pub fn help(cmd: &str, about: &str, spec: &[OptSpec]) -> String {
    let mut s = format!("{cmd} — {about}\n\noptions:\n");
    for o in spec {
        let val = if o.takes_value { " <value>" } else { "" };
        let def = o.default.map(|d| format!(" [default: {d}]")).unwrap_or_default();
        s.push_str(&format!("  --{}{}\n      {}{}\n", o.name, val, o.help, def));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> Vec<OptSpec> {
        vec![
            OptSpec { name: "nodes", takes_value: true, default: Some("4"), help: "node count" },
            OptSpec { name: "verbose", takes_value: false, default: None, help: "chatty" },
            OptSpec { name: "out", takes_value: true, default: None, help: "output path" },
        ]
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_key_value_and_equals() {
        let a = Args::parse(&sv(&["--nodes", "8", "--out=x.csv", "pos1"]), &spec()).unwrap();
        assert_eq!(a.get("nodes"), Some("8"));
        assert_eq!(a.get("out"), Some("x.csv"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(&sv(&[]), &spec()).unwrap();
        assert_eq!(a.get_parsed::<u32>("nodes").unwrap(), 4);
        assert_eq!(a.get("out"), None);
    }

    #[test]
    fn flags_and_unknown() {
        let a = Args::parse(&sv(&["--verbose"]), &spec()).unwrap();
        assert!(a.flag("verbose"));
        assert!(Args::parse(&sv(&["--nope"]), &spec()).is_err());
        assert!(Args::parse(&sv(&["--verbose=1"]), &spec()).is_err());
        assert!(Args::parse(&sv(&["--out"]), &spec()).is_err());
    }

    #[test]
    fn lists_parse() {
        let a = Args::parse(&sv(&["--nodes", "1,2,4"]), &spec()).unwrap();
        assert_eq!(a.get_list::<usize>("nodes").unwrap(), vec![1, 2, 4]);
    }

    #[test]
    fn help_mentions_options() {
        let h = help("bench", "run benchmarks", &spec());
        assert!(h.contains("--nodes"));
        assert!(h.contains("default: 4"));
    }
}
