//! xoshiro256** — a small, fast, high-quality PRNG (Blackman & Vigna).
//! Used by the property-test harness, workload generators and the fabric's
//! jitter model. Deterministic given a seed, which keeps every test and
//! benchmark reproducible.

/// xoshiro256** state.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 as recommended by the xoshiro authors (avoids
    /// all-zero and low-entropy states).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift (unbiased enough
    /// for test workloads; bound must be nonzero).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo < hi);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Fill a byte buffer (workload payloads).
    pub fn fill_bytes(&mut self, buf: &mut [u8]) {
        let mut chunks = buf.chunks_exact_mut(8);
        for c in &mut chunks {
            c.copy_from_slice(&self.next_u64().to_le_bytes());
        }
        let rem = chunks.into_remainder();
        if !rem.is_empty() {
            let b = self.next_u64().to_le_bytes();
            rem.copy_from_slice(&b[..rem.len()]);
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range(0, xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn f64_in_unit_interval_and_spread() {
        let mut r = Rng::new(9);
        let mut lo_half = 0usize;
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            if x < 0.5 {
                lo_half += 1;
            }
        }
        assert!((4_000..6_000).contains(&lo_half), "{lo_half}");
    }

    #[test]
    fn fill_bytes_covers_tail() {
        let mut r = Rng::new(3);
        let mut buf = [0u8; 13];
        r.fill_bytes(&mut buf);
        assert!(buf.iter().any(|&b| b != 0));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
