//! xoshiro256** — a small, fast, high-quality PRNG (Blackman & Vigna).
//!
//! This is the **single seeded randomness source** of the whole stack: the
//! property-test harness ([`super::prop`]), the chaos fault injector
//! ([`crate::sim::chaos`]), the random program generator
//! ([`crate::sim::proggen`]) and workload generators all derive their
//! streams from here, so every test failure can print the seed that
//! reproduces it. Independent streams are carved out of one seed with
//! [`Rng::split`] (decorrelated child generators) rather than ad-hoc seed
//! arithmetic; seeds come in from the environment through [`env_seed`].

/// xoshiro256** state.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

/// Parse a seed string: decimal, or hex with an `0x` prefix.
pub fn parse_seed(s: &str) -> Option<u64> {
    let t = s.trim();
    match t.strip_prefix("0x").or_else(|| t.strip_prefix("0X")) {
        Some(h) => u64::from_str_radix(h, 16).ok(),
        None => t.parse().ok(),
    }
}

/// Read a seed from the environment variable `var` (decimal or `0x` hex),
/// falling back to `default` when unset or malformed. Tests use this so a
/// failing run can be replayed with `VAR=<seed printed in the failure>`.
pub fn env_seed(var: &str, default: u64) -> u64 {
    std::env::var(var).ok().and_then(|v| parse_seed(&v)).unwrap_or(default)
}

impl Rng {
    /// Seed via SplitMix64 as recommended by the xoshiro authors (avoids
    /// all-zero and low-entropy states).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift (unbiased enough
    /// for test workloads; bound must be nonzero).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo < hi);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Fill a byte buffer (workload payloads).
    pub fn fill_bytes(&mut self, buf: &mut [u8]) {
        let mut chunks = buf.chunks_exact_mut(8);
        for c in &mut chunks {
            c.copy_from_slice(&self.next_u64().to_le_bytes());
        }
        let rem = chunks.into_remainder();
        if !rem.is_empty() {
            let b = self.next_u64().to_le_bytes();
            rem.copy_from_slice(&b[..rem.len()]);
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range(0, xs.len())]
    }

    /// Split off an independent child generator. The child is seeded from
    /// the parent's output run back through SplitMix64 (see [`Rng::new`]),
    /// so parent and child streams are decorrelated; the parent advances
    /// by one draw. This is how one top-level seed fans out into per-rank
    /// chaos streams, per-phase payload streams, etc.
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64() ^ 0x6C62_272E_07BB_0142)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn f64_in_unit_interval_and_spread() {
        let mut r = Rng::new(9);
        let mut lo_half = 0usize;
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            if x < 0.5 {
                lo_half += 1;
            }
        }
        assert!((4_000..6_000).contains(&lo_half), "{lo_half}");
    }

    #[test]
    fn fill_bytes_covers_tail() {
        let mut r = Rng::new(3);
        let mut buf = [0u8; 13];
        r.fill_bytes(&mut buf);
        assert!(buf.iter().any(|&b| b != 0));
    }

    #[test]
    fn split_streams_are_independent_and_deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        let mut ca = a.split();
        let mut cb = b.split();
        // Same parent seed → same child stream.
        for _ in 0..32 {
            assert_eq!(ca.next_u64(), cb.next_u64());
        }
        // Child and (advanced) parent streams differ.
        let same = (0..64).filter(|_| a.next_u64() == ca.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn seed_parsing_accepts_decimal_and_hex() {
        assert_eq!(parse_seed("42"), Some(42));
        assert_eq!(parse_seed(" 42 "), Some(42));
        assert_eq!(parse_seed("0xDEAD"), Some(0xDEAD));
        assert_eq!(parse_seed("0Xff"), Some(255));
        assert_eq!(parse_seed("wat"), None);
        assert_eq!(parse_seed(""), None);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
