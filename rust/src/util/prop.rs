//! A tiny property-based testing harness (no `proptest` in this offline
//! environment). Runs a property over many seeded random cases and, on
//! failure, performs greedy input shrinking via user-provided simplifiers.
//!
//! Used by the integration tests for datatype pack/unpack roundtrips, group
//! algebra, matching-order invariants and collective-vs-oracle checks.

use super::rng::Rng;

/// Configuration for a property run.
#[derive(Debug, Clone, Copy)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    pub max_shrink_steps: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 128, seed: 0xFE44_0401, max_shrink_steps: 256 }
    }
}

/// Run `prop` on `cases` random inputs produced by `gen`. On the first
/// failing input, greedily shrink with `shrink` (which yields candidate
/// simplifications) and panic with the minimal failing case.
pub fn check<T, G, P, S>(cfg: Config, mut gen: G, mut prop: P, shrink: S)
where
    T: std::fmt::Debug + Clone,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
    S: Fn(&T) -> Vec<T>,
{
    let mut rng = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            // Shrink.
            let mut best = input.clone();
            let mut best_msg = msg;
            let mut steps = 0;
            'outer: loop {
                for cand in shrink(&best) {
                    if steps >= cfg.max_shrink_steps {
                        break 'outer;
                    }
                    steps += 1;
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        continue 'outer;
                    }
                }
                break;
            }
            panic!(
                "property failed (case {case}, seed {}):\n  input (shrunk): {best:?}\n  error: {best_msg}",
                cfg.seed
            );
        }
    }
}

/// Convenience: no shrinking.
pub fn check_no_shrink<T, G, P>(cfg: Config, gen: G, prop: P)
where
    T: std::fmt::Debug + Clone,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    check(cfg, gen, prop, |_| Vec::new());
}

/// Standard shrinker for vectors: try removing halves, single elements, and
/// simplifying elements to a "smaller" value.
pub fn shrink_vec<T: Clone>(xs: &[T], simplify_elem: impl Fn(&T) -> Option<T>) -> Vec<Vec<T>> {
    let mut out = Vec::new();
    let n = xs.len();
    if n == 0 {
        return out;
    }
    out.push(xs[..n / 2].to_vec());
    out.push(xs[n / 2..].to_vec());
    if n <= 16 {
        for i in 0..n {
            let mut v = xs.to_vec();
            v.remove(i);
            out.push(v);
        }
        for i in 0..n {
            if let Some(s) = simplify_elem(&xs[i]) {
                let mut v = xs.to_vec();
                v[i] = s;
                out.push(v);
            }
        }
    }
    out
}

/// Standard shrinker for unsigned sizes: 0, halves, decrement.
pub fn shrink_usize(x: usize) -> Vec<usize> {
    let mut out = Vec::new();
    if x > 0 {
        out.push(0);
        out.push(x / 2);
        out.push(x - 1);
    }
    out.dedup();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        check_no_shrink(
            Config { cases: 64, ..Default::default() },
            |r| r.range(0, 100),
            |&x| if x < 100 { Ok(()) } else { Err("out of range".into()) },
        );
    }

    #[test]
    fn failing_property_shrinks_to_minimal() {
        // Property: all vectors have length < 10. Generator produces
        // length 0..32; the shrinker should find something close to len 10.
        let result = std::panic::catch_unwind(|| {
            check(
                Config { cases: 200, seed: 11, max_shrink_steps: 500 },
                |r| {
                    let n = r.range(0, 32);
                    (0..n).map(|i| i as u32).collect::<Vec<u32>>()
                },
                |v| if v.len() < 10 { Ok(()) } else { Err(format!("len {}", v.len())) },
                |v| shrink_vec(v, |_| None),
            )
        });
        let msg = match result {
            Err(e) => *e.downcast::<String>().unwrap(),
            Ok(()) => panic!("property should have failed"),
        };
        // The shrunk failing input should be exactly length 10 (minimal).
        assert!(msg.contains("len 10"), "shrinking did not minimize: {msg}");
    }

    #[test]
    fn shrink_usize_monotone() {
        for x in [1usize, 2, 17, 1024] {
            for s in shrink_usize(x) {
                assert!(s < x);
            }
        }
        assert!(shrink_usize(0).is_empty());
    }
}
