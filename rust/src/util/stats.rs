//! Aggregation helpers for the benchmark harness. The paper reports
//! *"each measurement is repeated 10 times and averaged"* and *"each data
//! point represents the geometric mean over the 11 MPI operations"* — so we
//! need arithmetic means per (op, msglen, nodes) cell and geometric means
//! across ops.

/// Arithmetic mean. Empty input returns NaN.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Geometric mean via log-sum (robust to underflow for many small values).
/// Empty input returns NaN; any non-positive value returns NaN (runtimes are
/// strictly positive).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() || xs.iter().any(|&x| x <= 0.0) {
        return f64::NAN;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Sample standard deviation (n-1 denominator); NaN for n < 2.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return f64::NAN;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Minimum (NaN for empty).
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NAN, f64::min)
}

/// Maximum (NaN for empty).
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NAN, f64::max)
}

/// Percentile with linear interpolation, p in [0, 100]. Sorts a copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = p / 100.0 * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Summary statistics bundle used in benchmark reports.
#[derive(Debug, Clone, Copy)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub p50: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        Summary {
            n: xs.len(),
            mean: mean(xs),
            stddev: stddev(xs),
            min: min(xs),
            p50: percentile(xs, 50.0),
            p99: percentile(xs, 99.0),
            max: max(xs),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!(mean(&[]).is_nan());
    }

    #[test]
    fn geomean_basic() {
        let g = geomean(&[1.0, 100.0]);
        assert!((g - 10.0).abs() < 1e-12, "{g}");
        assert!(geomean(&[1.0, 0.0]).is_nan());
        assert!(geomean(&[]).is_nan());
    }

    #[test]
    fn geomean_is_scale_equivariant() {
        let xs = [3.0, 7.0, 11.0, 0.5];
        let scaled: Vec<f64> = xs.iter().map(|x| x * 4.0).collect();
        assert!((geomean(&scaled) - 4.0 * geomean(&xs)).abs() < 1e-12);
    }

    #[test]
    fn stddev_known_value() {
        // Variance of [2,4,4,4,5,5,7,9] (sample) = 32/7.
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((stddev(&xs) - (32.0f64 / 7.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 100.0), 40.0);
        assert!((percentile(&xs, 50.0) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn summary_consistent() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        let s = Summary::of(&xs);
        assert_eq!(s.n, 5);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
    }
}
