//! A small benchmarking harness (no `criterion` in this offline
//! environment). `cargo bench` targets use `harness = false` and drive this
//! directly. It performs warmup, calibrates an iteration count to a target
//! sample time, collects per-sample means and reports summary statistics.

use super::stats::{self, Summary};
use super::timing::{fmt_ns, Stopwatch};

/// One benchmark's collected result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    /// Mean ns/iter per sample.
    pub samples_ns: Vec<f64>,
    pub iters_per_sample: u64,
}

impl BenchResult {
    pub fn summary(&self) -> Summary {
        Summary::of(&self.samples_ns)
    }

    pub fn mean_ns(&self) -> f64 {
        stats::mean(&self.samples_ns)
    }
}

/// Harness configuration.
#[derive(Debug, Clone, Copy)]
pub struct BenchConfig {
    pub warmup_s: f64,
    pub sample_s: f64,
    pub samples: usize,
    /// Cap on iterations per sample (for expensive bodies).
    pub max_iters: u64,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig { warmup_s: 0.2, sample_s: 0.05, samples: 12, max_iters: 1 << 24 }
    }
}

/// Quick config for CI-sized runs (used by `cargo bench` targets so the
/// whole suite stays under a couple of minutes).
pub fn quick() -> BenchConfig {
    BenchConfig { warmup_s: 0.05, sample_s: 0.02, samples: 6, max_iters: 1 << 22 }
}

/// A named collection of benchmarks that prints criterion-like lines.
pub struct Bench {
    cfg: BenchConfig,
    pub results: Vec<BenchResult>,
}

impl Bench {
    pub fn new(cfg: BenchConfig) -> Self {
        Bench { cfg, results: Vec::new() }
    }

    /// Benchmark a closure. The closure's return value is black-boxed to
    /// keep the optimizer honest.
    pub fn run<T>(&mut self, name: &str, mut body: impl FnMut() -> T) -> &BenchResult {
        // Warmup + calibration: find iters such that one sample ≈ sample_s.
        let sw = Stopwatch::start();
        let mut iters: u64 = 1;
        let mut elapsed;
        loop {
            let s = Stopwatch::start();
            for _ in 0..iters {
                std::hint::black_box(body());
            }
            elapsed = s.elapsed_s();
            if sw.elapsed_s() >= self.cfg.warmup_s && elapsed >= self.cfg.sample_s / 2.0 {
                break;
            }
            if elapsed < self.cfg.sample_s / 2.0 && iters < self.cfg.max_iters {
                let growth = if elapsed <= 0.0 {
                    8.0
                } else {
                    (self.cfg.sample_s / elapsed).clamp(1.5, 8.0)
                };
                iters = ((iters as f64 * growth) as u64).min(self.cfg.max_iters);
            }
        }
        // Measurement.
        let mut samples = Vec::with_capacity(self.cfg.samples);
        for _ in 0..self.cfg.samples {
            let s = Stopwatch::start();
            for _ in 0..iters {
                std::hint::black_box(body());
            }
            samples.push(s.elapsed_ns() as f64 / iters as f64);
        }
        let res = BenchResult { name: name.to_string(), samples_ns: samples, iters_per_sample: iters };
        let sum = res.summary();
        println!(
            "bench {:<56} {:>12}/iter  (±{:>10}, n={}, iters={})",
            res.name,
            fmt_ns(sum.mean),
            fmt_ns(sum.stddev),
            sum.n,
            res.iters_per_sample
        );
        self.results.push(res);
        self.results.last().unwrap()
    }

    /// Ratio of two named results' means (for overhead reporting).
    pub fn ratio(&self, num: &str, den: &str) -> Option<f64> {
        let n = self.results.iter().find(|r| r.name == num)?.mean_ns();
        let d = self.results.iter().find(|r| r.name == den)?.mean_ns();
        Some(n / d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_and_orders_costs() {
        let mut b = Bench::new(BenchConfig {
            warmup_s: 0.01,
            sample_s: 0.005,
            samples: 4,
            max_iters: 1 << 20,
        });
        b.run("cheap", || 1u64 + 1);
        b.run("expensive", || (0..2000u64).map(std::hint::black_box).sum::<u64>());
        let cheap = b.results[0].mean_ns();
        let exp = b.results[1].mean_ns();
        assert!(exp > cheap * 5.0, "cheap={cheap} expensive={exp}");
        let r = b.ratio("expensive", "cheap").unwrap();
        assert!(r > 5.0);
    }
}
