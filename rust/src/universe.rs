//! The job launcher: spawns one OS thread per simulated rank, builds
//! `MPI_COMM_WORLD`, runs an SPMD closure on every rank and joins.
//!
//! A [`Universe`] describes a *cluster shape* (nodes × ranks-per-node and a
//! network model); every [`Universe::run`] is one job on a fresh fabric.
//!
//! Two testing facilities ride on the launcher (see [`crate::sim`]):
//!
//! * **Chaos mode** — a seeded [`ChaosConfig`] perturbs the job's
//!   schedule within legal MPI semantics (delivery delays, cross-sender
//!   reordering, yield jitter, eager-limit randomization, pool pressure).
//!   Enabled per-universe with [`Universe::with_chaos`] /
//!   [`Universe::chaotic`], or globally via `FERROMPI_CHAOS_SEED` / the
//!   `chaos_*` cvars (every constructor consults
//!   [`ChaosConfig::from_env`]).
//! * **Quiescence auditing** — after each rank's closure returns (and
//!   again after the join), the runtime state is checked for residue:
//!   undrained queues, non-terminal requests, leaked wire buffers. On by
//!   default for chaos jobs, or via `FERROMPI_AUDIT=1` /
//!   [`Universe::audited`].

use crate::comm::Comm;
use crate::p2p::RankCtx;
use crate::sim::audit;
use crate::sim::chaos::ChaosConfig;
use crate::transport::{Fabric, NetworkModel, NodeMap};
use std::sync::Arc;

/// A simulated cluster allocation.
#[derive(Debug, Clone, Copy)]
pub struct Universe {
    pub nodemap: NodeMap,
    pub model: NetworkModel,
    /// Seeded schedule perturbation for every job this universe runs
    /// (`None` = faithful fabric).
    pub chaos: Option<ChaosConfig>,
    /// Quiescence-audit override: `Some(on)` forces it, `None` defers to
    /// `FERROMPI_AUDIT` and then to "on iff chaos".
    pub audit: Option<bool>,
}

impl Universe {
    /// `nodes` × `ppn` ranks on the Omni-Path-class model (the paper's
    /// CLAIX-2018 shape), with any MPI_T cvar overrides applied.
    pub fn new(nodes: usize, ppn: usize) -> Universe {
        let mut model = NetworkModel::omnipath();
        crate::tool::cvar::apply_model_overrides(&mut model);
        Universe {
            nodemap: NodeMap::new(nodes, ppn),
            model,
            chaos: ChaosConfig::from_env(),
            audit: None,
        }
    }

    /// Custom network model.
    pub fn with_model(nodes: usize, ppn: usize, model: NetworkModel) -> Universe {
        Universe {
            nodemap: NodeMap::new(nodes, ppn),
            model,
            chaos: ChaosConfig::from_env(),
            audit: None,
        }
    }

    /// Like [`Universe::new`], but the cluster shape can be overridden
    /// from the environment: `FERROMPI_NODES` / `FERROMPI_PPN` (positive
    /// integers; malformed or missing values fall back to the given
    /// defaults). Benches and examples use this so a sweep can be
    /// re-shaped without recompiling.
    pub fn from_env(default_nodes: usize, default_ppn: usize) -> Universe {
        let nodes = std::env::var("FERROMPI_NODES").ok();
        let ppn = std::env::var("FERROMPI_PPN").ok();
        let (n, p) = resolve_shape(nodes.as_deref(), ppn.as_deref(), default_nodes, default_ppn);
        // Under `ferrompi-launch` the world size is fixed by the
        // launcher: a disagreeing FERROMPI_NODES × FERROMPI_PPN is a
        // configuration error, never a silent reshape.
        if let Ok(w) = std::env::var(crate::coordinator::launch::ENV_WORLD) {
            if let Ok(world) = w.trim().parse::<usize>() {
                if let Err(e) =
                    crate::coordinator::launch::validate_launched_shape(n, p, world)
                {
                    panic!("{e}");
                }
            }
        }
        Universe::new(n, p)
    }

    /// Single-node job with the zero-cost model: what correctness tests
    /// use (no virtual-time effects, pure software paths). Still picks up
    /// a `FERROMPI_CHAOS_SEED` from the environment, so the whole test
    /// suite can be soaked under (schedule-only) chaos without edits.
    pub fn test(nranks: usize) -> Universe {
        Universe {
            nodemap: NodeMap::new(1, nranks),
            model: NetworkModel::zero(),
            chaos: ChaosConfig::from_env(),
            audit: None,
        }
    }

    /// This universe with a full chaos plan.
    pub fn with_chaos(mut self, cfg: ChaosConfig) -> Universe {
        self.chaos = Some(cfg);
        self
    }

    /// This universe perturbed by the plan derived from `seed`
    /// ([`ChaosConfig::from_seed`]).
    pub fn chaotic(self, seed: u64) -> Universe {
        self.with_chaos(ChaosConfig::from_seed(seed))
    }

    /// This universe with chaos disabled (the differential harness's
    /// baseline, immune to a process-global `FERROMPI_CHAOS_SEED`).
    pub fn calm(mut self) -> Universe {
        self.chaos = None;
        self
    }

    /// Force the end-of-job quiescence audit on or off.
    pub fn audited(mut self, on: bool) -> Universe {
        self.audit = Some(on);
        self
    }

    fn audit_on(&self) -> bool {
        self.audit.unwrap_or_else(|| env_audit().unwrap_or(self.chaos.is_some()))
    }

    pub fn nranks(&self) -> usize {
        self.nodemap.nranks()
    }

    /// Run one SPMD job: `f` executes on every rank with its
    /// `MPI_COMM_WORLD`; returns the per-rank results in rank order.
    /// A panic on any rank is propagated (after all threads are joined).
    pub fn run<T: Send>(&self, f: impl Fn(&Comm) -> T + Send + Sync) -> Vec<T> {
        self.run_inner(f).0
    }

    /// Run and also return the fabric statistics of the job (used by tool
    /// tests and the benchmark reports).
    pub fn run_with_stats<T: Send>(
        &self,
        f: impl Fn(&Comm) -> T + Send + Sync,
    ) -> (Vec<T>, Arc<Fabric>) {
        self.run_inner(f)
    }

    fn run_inner<T: Send>(&self, f: impl Fn(&Comm) -> T + Send + Sync) -> (Vec<T>, Arc<Fabric>) {
        // A process spawned by `ferrompi-launch` hosts exactly one rank:
        // its first run consumes the launch environment instead of
        // spawning rank threads.
        match crate::coordinator::launch::take_launched_job() {
            Ok(None) => {}
            Ok(Some(job)) => return self.run_launched(f, job),
            Err(e) => panic!("{e}"),
        }
        let n = self.nranks();
        let mut model = self.model;
        if let Some(ch) = &self.chaos {
            // One of the chaos axes: each job draws its eager/rendezvous
            // threshold from a seed-derived sweep.
            model.eager_threshold = ch.pick_eager_threshold(model.eager_threshold);
        }
        let audit = self.audit_on();
        let fabric = Arc::new(Fabric::with_chaos(self.nodemap, model, self.chaos));
        let out = std::thread::scope(|s| {
            let handles: Vec<_> = (0..n)
                .map(|r| {
                    let fabric = fabric.clone();
                    let f = &f;
                    s.spawn(move || {
                        let ctx = RankCtx::new(r, fabric);
                        let comm = Comm::world(ctx.clone());
                        let out = f(&comm);
                        drop(comm);
                        // Drain the flow-control ledger before the thread
                        // dies: parked sends are payloads peers still
                        // wait on, and owed credit returns are what lets
                        // *their* quiescence terminate. Runs regardless
                        // of auditing — it is a liveness step, not a
                        // check.
                        if let Err(e) = crate::p2p::engine::quiesce_flow(&ctx) {
                            panic!("rank {r} failed closing its flow ledger: {e}");
                        }
                        if audit {
                            // Rank-local state dies with this thread: this
                            // is the last moment it can be checked.
                            audit::enforce_rank(&ctx);
                        }
                        out
                    })
                })
                .collect();
            let mut results = Vec::with_capacity(n);
            let mut panic: Option<Box<dyn std::any::Any + Send>> = None;
            for h in handles {
                match h.join() {
                    Ok(v) => results.push(v),
                    Err(e) => {
                        if panic.is_none() {
                            panic = Some(e);
                        }
                    }
                }
            }
            if let Some(p) = panic {
                // A red chaos run dumps its schedule-pressure trace before
                // unwinding, so the failure is replayable from the output —
                // unless the panic message already embeds it (quiescence
                // audit reports do), which would print the ring twice.
                let already_dumped = p
                    .downcast_ref::<String>()
                    .is_some_and(|m| m.contains("FERROMPI_CHAOS_SEED="));
                if fabric.trace.enabled() && !already_dumped {
                    eprintln!("{}", fabric.trace_report());
                }
                std::panic::resume_unwind(p);
            }
            results
        });
        if audit {
            audit::enforce_fabric(&fabric);
        }
        (out, fabric)
    }

    /// Run this process's single rank of a launched multi-process job.
    /// The cluster shape comes from the launch environment (mpiexec
    /// semantics: the launcher's `-n/--nodes/--ppn` override whatever
    /// shape this universe was constructed with); chaos is ignored —
    /// perturbation requires the in-process backend. The returned vector
    /// holds only the local rank's result.
    fn run_launched<T: Send>(
        &self,
        f: impl Fn(&Comm) -> T + Send + Sync,
        job: crate::coordinator::launch::LaunchedJob,
    ) -> (Vec<T>, Arc<Fabric>) {
        use crate::transport::backend::{BackendKind, BackendStats};
        use crate::transport::wire::BufferPool;
        let nodemap = NodeMap::new(job.nodes, job.ppn);
        let pool = Arc::new(BufferPool::new());
        let bstats = Arc::new(BackendStats::default());
        let backend: Box<dyn crate::transport::backend::Backend> = match job.backend {
            BackendKind::Inproc => unreachable!("launch rejects inproc for launched workers"),
            #[cfg(unix)]
            BackendKind::Shm => {
                let path = job.shm_path.as_ref().expect("launch sets the shm path");
                let seg = crate::transport::shm::ShmSegment::open(path, job.world)
                    .unwrap_or_else(|e| panic!("rank {}: {e}", job.rank));
                Box::new(crate::transport::shm::ShmBackend::new(
                    Arc::new(seg),
                    job.rank,
                    Arc::clone(&pool),
                    Arc::clone(&bstats),
                ))
            }
            #[cfg(not(unix))]
            BackendKind::Shm => panic!("the shm backend requires a unix platform"),
            BackendKind::Socket => Box::new(crate::transport::socket::SocketBackend::start(
                job.listener.expect("launch binds the fabric listener"),
                job.rank,
                job.addrs.clone(),
                Arc::clone(&pool),
                Arc::clone(&bstats),
            )),
        };
        let flow = crate::transport::FlowConfig::resolve(nodemap.nranks(), false)
            .unwrap_or_else(|e| panic!("{e}"));
        let fabric = Arc::new(Fabric::multiprocess(
            nodemap, self.model, job.rank, pool, backend, bstats, flow,
        ));
        let audit = self.audit_on();
        let ctx = RankCtx::new(job.rank, fabric.clone());
        let comm = Comm::world(ctx.clone());
        let out = f(&comm);
        // Quiesce the whole job before tearing the transport down: a
        // fast rank closing its sockets mid-collective would look like a
        // peer failure to the others.
        crate::collective::barrier(&comm).expect("final launched-job barrier");
        // The barrier bounds closure skew across processes; the flow
        // ledger then drains within the quiesce grace period.
        if let Err(e) = crate::p2p::engine::quiesce_flow(&ctx) {
            panic!("rank {} failed closing its flow ledger: {e}", job.rank);
        }
        drop(comm);
        if audit {
            audit::enforce_rank(&ctx);
            // Fabric-global checks are per-process here: remote ranks'
            // queues are audited by their own processes.
            audit::enforce_fabric(&fabric);
        }
        fabric.shutdown_backend();
        (vec![out], fabric)
    }
}

/// `FERROMPI_AUDIT` as a tri-state: unset/unrecognized → `None`.
fn env_audit() -> Option<bool> {
    match std::env::var("FERROMPI_AUDIT") {
        Ok(v) => parse_audit(&v),
        Err(_) => None,
    }
}

/// Pure parser behind [`env_audit`] (unit-tested without process state).
fn parse_audit(v: &str) -> Option<bool> {
    match v.trim() {
        "1" | "on" | "true" => Some(true),
        "0" | "off" | "false" => Some(false),
        _ => None,
    }
}

/// Pure shape resolver behind [`Universe::from_env`] (unit-tested without
/// touching the process environment): each dimension independently takes
/// the env value when it parses to a positive integer, else the default.
fn resolve_shape(
    nodes: Option<&str>,
    ppn: Option<&str>,
    default_nodes: usize,
    default_ppn: usize,
) -> (usize, usize) {
    let dim = |v: Option<&str>, d: usize| {
        v.and_then(|s| s.trim().parse::<usize>().ok()).filter(|&n| n > 0).unwrap_or(d)
    };
    (dim(nodes, default_nodes), dim(ppn, default_ppn))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_resolver_precedence() {
        assert_eq!(resolve_shape(None, None, 4, 2), (4, 2));
        assert_eq!(resolve_shape(Some("8"), None, 4, 2), (8, 2));
        assert_eq!(resolve_shape(Some(" 8 "), Some("3"), 4, 2), (8, 3));
        assert_eq!(resolve_shape(Some("0"), Some("-1"), 4, 2), (4, 2));
        assert_eq!(resolve_shape(Some("wat"), Some("1"), 4, 2), (4, 1));
    }

    #[test]
    fn audit_parser_tristate() {
        assert_eq!(parse_audit("1"), Some(true));
        assert_eq!(parse_audit(" on "), Some(true));
        assert_eq!(parse_audit("true"), Some(true));
        assert_eq!(parse_audit("0"), Some(false));
        assert_eq!(parse_audit("off"), Some(false));
        assert_eq!(parse_audit("wat"), None);
        assert_eq!(parse_audit(""), None);
    }

    #[test]
    fn world_identity() {
        let u = Universe::test(4);
        let ranks = u.run(|comm| (comm.rank(), comm.size()));
        assert_eq!(ranks, vec![(0, 4), (1, 4), (2, 4), (3, 4)]);
    }

    #[test]
    fn fresh_fabric_per_run() {
        let u = Universe::test(2);
        for _ in 0..3 {
            let sums = u.run(|comm| comm.rank());
            assert_eq!(sums, vec![0, 1]);
        }
    }

    #[test]
    #[should_panic(expected = "rank boom")]
    fn rank_panic_propagates() {
        let u = Universe::test(2);
        u.run(|comm| {
            if comm.rank() == 1 {
                panic!("rank boom");
            }
        });
    }

    #[test]
    fn chaos_builders_compose() {
        let u = Universe::test(2).chaotic(42);
        assert_eq!(u.chaos.map(|c| c.seed), Some(42));
        assert!(u.audit_on(), "chaos implies auditing by default");
        let calm = u.calm();
        assert!(calm.chaos.is_none());
        assert!(calm.audited(true).audit_on());
        assert!(!u.audited(false).audit_on(), "explicit override wins");
    }

    #[test]
    fn chaotic_run_produces_correct_results_and_audits_clean() {
        // A perturbed fabric must not change observable results.
        let u = Universe::test(3).chaotic(0xD15EA5E).audited(true);
        let ranks = u.run(|comm| (comm.rank(), comm.size()));
        assert_eq!(ranks, vec![(0, 3), (1, 3), (2, 3)]);
    }

    #[test]
    #[should_panic(expected = "quiescence audit failed")]
    fn audit_flags_an_unreceived_message() {
        let u = Universe::test(2).audited(true);
        u.run(|comm| {
            let byte = crate::datatype::Datatype::primitive(crate::datatype::Primitive::Byte);
            if comm.rank() == 0 {
                // Fire-and-forget eager send nobody receives: quiescence
                // audit on rank 1 must flag the unexpected-queue residue.
                comm.send(&[1u8, 2, 3], 3, &byte, 1, 9).unwrap();
            }
            crate::collective::barrier(comm).unwrap();
        });
    }
}
