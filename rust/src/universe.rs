//! The job launcher: spawns one OS thread per simulated rank, builds
//! `MPI_COMM_WORLD`, runs an SPMD closure on every rank and joins.
//!
//! A [`Universe`] describes a *cluster shape* (nodes × ranks-per-node and a
//! network model); every [`Universe::run`] is one job on a fresh fabric.

use crate::comm::Comm;
use crate::p2p::RankCtx;
use crate::transport::{Fabric, NetworkModel, NodeMap};
use std::sync::Arc;

/// A simulated cluster allocation.
#[derive(Debug, Clone, Copy)]
pub struct Universe {
    pub nodemap: NodeMap,
    pub model: NetworkModel,
}

impl Universe {
    /// `nodes` × `ppn` ranks on the Omni-Path-class model (the paper's
    /// CLAIX-2018 shape), with any MPI_T cvar overrides applied.
    pub fn new(nodes: usize, ppn: usize) -> Universe {
        let mut model = NetworkModel::omnipath();
        crate::tool::cvar::apply_model_overrides(&mut model);
        Universe { nodemap: NodeMap::new(nodes, ppn), model }
    }

    /// Custom network model.
    pub fn with_model(nodes: usize, ppn: usize, model: NetworkModel) -> Universe {
        Universe { nodemap: NodeMap::new(nodes, ppn), model }
    }

    /// Single-node job with the zero-cost model: what correctness tests
    /// use (no virtual-time effects, pure software paths).
    pub fn test(nranks: usize) -> Universe {
        Universe { nodemap: NodeMap::new(1, nranks), model: NetworkModel::zero() }
    }

    pub fn nranks(&self) -> usize {
        self.nodemap.nranks()
    }

    /// Run one SPMD job: `f` executes on every rank with its
    /// `MPI_COMM_WORLD`; returns the per-rank results in rank order.
    /// A panic on any rank is propagated (after all threads are joined).
    pub fn run<T: Send>(&self, f: impl Fn(&Comm) -> T + Send + Sync) -> Vec<T> {
        let n = self.nranks();
        let fabric = Arc::new(Fabric::new(self.nodemap, self.model));
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..n)
                .map(|r| {
                    let fabric = fabric.clone();
                    let f = &f;
                    s.spawn(move || {
                        let ctx = RankCtx::new(r, fabric);
                        let comm = Comm::world(ctx);
                        f(&comm)
                    })
                })
                .collect();
            let mut results = Vec::with_capacity(n);
            let mut panic: Option<Box<dyn std::any::Any + Send>> = None;
            for h in handles {
                match h.join() {
                    Ok(v) => results.push(v),
                    Err(e) => {
                        if panic.is_none() {
                            panic = Some(e);
                        }
                    }
                }
            }
            if let Some(p) = panic {
                std::panic::resume_unwind(p);
            }
            results
        })
    }

    /// Run and also return the fabric statistics of the job (used by tool
    /// tests and the benchmark reports).
    pub fn run_with_stats<T: Send>(
        &self,
        f: impl Fn(&Comm) -> T + Send + Sync,
    ) -> (Vec<T>, Arc<Fabric>) {
        let n = self.nranks();
        let fabric = Arc::new(Fabric::new(self.nodemap, self.model));
        let out = std::thread::scope(|s| {
            let handles: Vec<_> = (0..n)
                .map(|r| {
                    let fabric = fabric.clone();
                    let f = &f;
                    s.spawn(move || {
                        let ctx = RankCtx::new(r, fabric);
                        let comm = Comm::world(ctx);
                        f(&comm)
                    })
                })
                .collect();
            let mut results = Vec::with_capacity(n);
            let mut panic: Option<Box<dyn std::any::Any + Send>> = None;
            for h in handles {
                match h.join() {
                    Ok(v) => results.push(v),
                    Err(e) => {
                        if panic.is_none() {
                            panic = Some(e);
                        }
                    }
                }
            }
            if let Some(p) = panic {
                std::panic::resume_unwind(p);
            }
            results
        });
        (out, fabric)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn world_identity() {
        let u = Universe::test(4);
        let ranks = u.run(|comm| (comm.rank(), comm.size()));
        assert_eq!(ranks, vec![(0, 4), (1, 4), (2, 4), (3, 4)]);
    }

    #[test]
    fn fresh_fabric_per_run() {
        let u = Universe::test(2);
        for _ in 0..3 {
            let sums = u.run(|comm| comm.rank());
            assert_eq!(sums, vec![0, 1]);
        }
    }

    #[test]
    #[should_panic(expected = "rank boom")]
    fn rank_panic_propagates() {
        let u = Universe::test(2);
        u.run(|comm| {
            if comm.rank() == 1 {
                panic!("rank boom");
            }
        });
    }
}
