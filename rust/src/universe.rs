//! The job launcher: spawns one OS thread per simulated rank, builds
//! `MPI_COMM_WORLD`, runs an SPMD closure on every rank and joins.
//!
//! A [`Universe`] describes a *cluster shape* (nodes × ranks-per-node and a
//! network model); every [`Universe::run`] is one job on a fresh fabric.

use crate::comm::Comm;
use crate::p2p::RankCtx;
use crate::transport::{Fabric, NetworkModel, NodeMap};
use std::sync::Arc;

/// A simulated cluster allocation.
#[derive(Debug, Clone, Copy)]
pub struct Universe {
    pub nodemap: NodeMap,
    pub model: NetworkModel,
}

impl Universe {
    /// `nodes` × `ppn` ranks on the Omni-Path-class model (the paper's
    /// CLAIX-2018 shape), with any MPI_T cvar overrides applied.
    pub fn new(nodes: usize, ppn: usize) -> Universe {
        let mut model = NetworkModel::omnipath();
        crate::tool::cvar::apply_model_overrides(&mut model);
        Universe { nodemap: NodeMap::new(nodes, ppn), model }
    }

    /// Custom network model.
    pub fn with_model(nodes: usize, ppn: usize, model: NetworkModel) -> Universe {
        Universe { nodemap: NodeMap::new(nodes, ppn), model }
    }

    /// Like [`Universe::new`], but the cluster shape can be overridden
    /// from the environment: `FERROMPI_NODES` / `FERROMPI_PPN` (positive
    /// integers; malformed or missing values fall back to the given
    /// defaults). Benches and examples use this so a sweep can be
    /// re-shaped without recompiling.
    pub fn from_env(default_nodes: usize, default_ppn: usize) -> Universe {
        let nodes = std::env::var("FERROMPI_NODES").ok();
        let ppn = std::env::var("FERROMPI_PPN").ok();
        let (n, p) = resolve_shape(nodes.as_deref(), ppn.as_deref(), default_nodes, default_ppn);
        Universe::new(n, p)
    }

    /// Single-node job with the zero-cost model: what correctness tests
    /// use (no virtual-time effects, pure software paths).
    pub fn test(nranks: usize) -> Universe {
        Universe { nodemap: NodeMap::new(1, nranks), model: NetworkModel::zero() }
    }

    pub fn nranks(&self) -> usize {
        self.nodemap.nranks()
    }

    /// Run one SPMD job: `f` executes on every rank with its
    /// `MPI_COMM_WORLD`; returns the per-rank results in rank order.
    /// A panic on any rank is propagated (after all threads are joined).
    pub fn run<T: Send>(&self, f: impl Fn(&Comm) -> T + Send + Sync) -> Vec<T> {
        let n = self.nranks();
        let fabric = Arc::new(Fabric::new(self.nodemap, self.model));
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..n)
                .map(|r| {
                    let fabric = fabric.clone();
                    let f = &f;
                    s.spawn(move || {
                        let ctx = RankCtx::new(r, fabric);
                        let comm = Comm::world(ctx);
                        f(&comm)
                    })
                })
                .collect();
            let mut results = Vec::with_capacity(n);
            let mut panic: Option<Box<dyn std::any::Any + Send>> = None;
            for h in handles {
                match h.join() {
                    Ok(v) => results.push(v),
                    Err(e) => {
                        if panic.is_none() {
                            panic = Some(e);
                        }
                    }
                }
            }
            if let Some(p) = panic {
                std::panic::resume_unwind(p);
            }
            results
        })
    }

    /// Run and also return the fabric statistics of the job (used by tool
    /// tests and the benchmark reports).
    pub fn run_with_stats<T: Send>(
        &self,
        f: impl Fn(&Comm) -> T + Send + Sync,
    ) -> (Vec<T>, Arc<Fabric>) {
        let n = self.nranks();
        let fabric = Arc::new(Fabric::new(self.nodemap, self.model));
        let out = std::thread::scope(|s| {
            let handles: Vec<_> = (0..n)
                .map(|r| {
                    let fabric = fabric.clone();
                    let f = &f;
                    s.spawn(move || {
                        let ctx = RankCtx::new(r, fabric);
                        let comm = Comm::world(ctx);
                        f(&comm)
                    })
                })
                .collect();
            let mut results = Vec::with_capacity(n);
            let mut panic: Option<Box<dyn std::any::Any + Send>> = None;
            for h in handles {
                match h.join() {
                    Ok(v) => results.push(v),
                    Err(e) => {
                        if panic.is_none() {
                            panic = Some(e);
                        }
                    }
                }
            }
            if let Some(p) = panic {
                std::panic::resume_unwind(p);
            }
            results
        });
        (out, fabric)
    }
}

/// Pure shape resolver behind [`Universe::from_env`] (unit-tested without
/// touching the process environment): each dimension independently takes
/// the env value when it parses to a positive integer, else the default.
fn resolve_shape(
    nodes: Option<&str>,
    ppn: Option<&str>,
    default_nodes: usize,
    default_ppn: usize,
) -> (usize, usize) {
    let dim = |v: Option<&str>, d: usize| {
        v.and_then(|s| s.trim().parse::<usize>().ok()).filter(|&n| n > 0).unwrap_or(d)
    };
    (dim(nodes, default_nodes), dim(ppn, default_ppn))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_resolver_precedence() {
        assert_eq!(resolve_shape(None, None, 4, 2), (4, 2));
        assert_eq!(resolve_shape(Some("8"), None, 4, 2), (8, 2));
        assert_eq!(resolve_shape(Some(" 8 "), Some("3"), 4, 2), (8, 3));
        assert_eq!(resolve_shape(Some("0"), Some("-1"), 4, 2), (4, 2));
        assert_eq!(resolve_shape(Some("wat"), Some("1"), 4, 2), (4, 1));
    }

    #[test]
    fn world_identity() {
        let u = Universe::test(4);
        let ranks = u.run(|comm| (comm.rank(), comm.size()));
        assert_eq!(ranks, vec![(0, 4), (1, 4), (2, 4), (3, 4)]);
    }

    #[test]
    fn fresh_fabric_per_run() {
        let u = Universe::test(2);
        for _ in 0..3 {
            let sums = u.run(|comm| comm.rank());
            assert_eq!(sums, vec![0, 1]);
        }
    }

    #[test]
    #[should_panic(expected = "rank boom")]
    fn rank_panic_propagates() {
        let u = Universe::test(2);
        u.run(|comm| {
            if comm.rank() == 1 {
                panic!("rank boom");
            }
        });
    }
}
