//! MPI error classes, error codes and error handlers.
//!
//! The paper: *"Error handling is performed by checking the return values of
//! viable MPI functions for success, throwing an exception otherwise. [...]
//! The exceptions provide an error code, which derives from the error class
//! as specified by the standard. Default error codes are available as
//! variables scoped in the `mpi::error` namespace."*
//!
//! In Rust the exception analog is [`MpiError`] carried through
//! `Result<T, MpiError>`; the `raw` layer converts it back to C-style
//! integer return codes, and the `panic-on-error` cargo feature mirrors the
//! paper's macro-enabled exception mode (the raw layer panics instead of
//! returning a code).

use std::fmt;

/// The predefined MPI-4.0 error classes (standard §9.4, table "Error
/// classes"). The integer values follow the conventional MPICH numbering so
/// the `raw` interface exposes familiar constants.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(i32)]
pub enum ErrorClass {
    Success = 0,
    Buffer = 1,
    Count = 2,
    Type = 3,
    Tag = 4,
    Comm = 5,
    Rank = 6,
    Request = 7,
    Root = 8,
    Group = 9,
    Op = 10,
    Topology = 11,
    Dims = 12,
    Arg = 13,
    Unknown = 14,
    Truncate = 15,
    Other = 16,
    Intern = 17,
    InStatus = 18,
    Pending = 19,
    Keyval = 20,
    NoMem = 21,
    Base = 22,
    InfoKey = 23,
    InfoValue = 24,
    InfoNokey = 25,
    Spawn = 26,
    Port = 27,
    Service = 28,
    Name = 29,
    Win = 30,
    Size = 31,
    Disp = 32,
    Info = 33,
    Locktype = 34,
    Assert = 35,
    RmaConflict = 36,
    RmaSync = 37,
    RmaRange = 38,
    RmaAttach = 39,
    RmaShared = 40,
    RmaFlavor = 41,
    File = 42,
    NotSame = 43,
    Amode = 44,
    UnsupportedDatarep = 45,
    UnsupportedOperation = 46,
    BadFile = 47,
    NoSuchFile = 48,
    FileExists = 49,
    FileInUse = 50,
    NoSpace = 51,
    Quota = 52,
    ReadOnly = 53,
    AccessDenied = 54,
    DupDatarep = 55,
    Conversion = 56,
    Io = 57,
    Session = 58,
    ProcAborted = 59,
    ValueTooLarge = 60,
    ErrPending = 61,
}

impl ErrorClass {
    /// The C-style integer error code for this class (`MPI_ERR_*`).
    pub const fn code(self) -> i32 {
        self as i32
    }

    /// Inverse of [`ErrorClass::code`], `MPI_Error_class` analog.
    pub fn from_code(code: i32) -> ErrorClass {
        use ErrorClass::*;
        const ALL: [ErrorClass; 62] = [
            Success, Buffer, Count, Type, Tag, Comm, Rank, Request, Root, Group, Op, Topology,
            Dims, Arg, Unknown, Truncate, Other, Intern, InStatus, Pending, Keyval, NoMem, Base,
            InfoKey, InfoValue, InfoNokey, Spawn, Port, Service, Name, Win, Size, Disp, Info,
            Locktype, Assert, RmaConflict, RmaSync, RmaRange, RmaAttach, RmaShared, RmaFlavor,
            File, NotSame, Amode, UnsupportedDatarep, UnsupportedOperation, BadFile, NoSuchFile,
            FileExists, FileInUse, NoSpace, Quota, ReadOnly, AccessDenied, DupDatarep, Conversion,
            Io, Session, ProcAborted, ValueTooLarge, ErrPending,
        ];
        ALL.get(code as usize).copied().unwrap_or(Unknown)
    }

    /// `MPI_Error_string` analog.
    pub fn as_str(self) -> &'static str {
        use ErrorClass::*;
        match self {
            Success => "no error",
            Buffer => "invalid buffer pointer",
            Count => "invalid count argument",
            Type => "invalid datatype argument",
            Tag => "invalid tag argument",
            Comm => "invalid communicator",
            Rank => "invalid rank",
            Request => "invalid request",
            Root => "invalid root",
            Group => "invalid group",
            Op => "invalid operation",
            Topology => "invalid topology",
            Dims => "invalid dimension argument",
            Arg => "invalid argument",
            Unknown => "unknown error",
            Truncate => "message truncated on receive",
            Other => "known error not in this list",
            Intern => "internal MPI error",
            InStatus => "error code is in status",
            Pending => "pending request",
            Keyval => "invalid keyval",
            NoMem => "out of memory",
            Base => "invalid base",
            InfoKey => "invalid info key",
            InfoValue => "invalid info value",
            InfoNokey => "info key not defined",
            Spawn => "spawn error",
            Port => "invalid port",
            Service => "invalid service",
            Name => "invalid name",
            Win => "invalid window",
            Size => "invalid size",
            Disp => "invalid displacement",
            Info => "invalid info object",
            Locktype => "invalid lock type",
            Assert => "invalid assert argument",
            RmaConflict => "conflicting RMA accesses",
            RmaSync => "invalid RMA synchronization",
            RmaRange => "RMA target outside window",
            RmaAttach => "memory cannot be attached",
            RmaShared => "memory cannot be shared",
            RmaFlavor => "wrong window flavor",
            File => "invalid file handle",
            NotSame => "collective argument mismatch across ranks",
            Amode => "invalid access mode",
            UnsupportedDatarep => "unsupported data representation",
            UnsupportedOperation => "unsupported file operation",
            BadFile => "invalid file name",
            NoSuchFile => "file does not exist",
            FileExists => "file exists",
            FileInUse => "file currently in use",
            NoSpace => "not enough space",
            Quota => "quota exceeded",
            ReadOnly => "read-only file or file system",
            AccessDenied => "permission denied",
            DupDatarep => "data representation already defined",
            Conversion => "data conversion error",
            Io => "I/O error",
            Session => "invalid session",
            ProcAborted => "peer process aborted",
            ValueTooLarge => "value too large to store",
            ErrPending => "operation still pending",
        }
    }
}

impl fmt::Display for ErrorClass {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// The exception analog: every fallible operation in the library returns
/// `Result<T, MpiError>`. The error carries its class (standard-specified)
/// plus a human-readable context message.
#[derive(Debug, Clone, thiserror::Error)]
#[error("MPI error ({}): {message}", class.as_str())]
pub struct MpiError {
    pub class: ErrorClass,
    pub message: String,
}

impl MpiError {
    pub fn new(class: ErrorClass, message: impl Into<String>) -> Self {
        MpiError { class, message: message.into() }
    }

    /// The integer error code (`MPI_Error_class` of the code is the class
    /// itself for all errors raised by this library).
    pub fn code(&self) -> i32 {
        self.class.code()
    }
}

/// Convenience constructor macro used throughout the substrate.
#[macro_export]
macro_rules! mpi_err {
    ($class:ident, $($arg:tt)*) => {
        $crate::error::MpiError::new($crate::error::ErrorClass::$class, format!($($arg)*))
    };
}

pub type Result<T> = std::result::Result<T, MpiError>;

/// Error handler semantics attached to communicators, windows and files
/// (`MPI_Errhandler`). `ErrorsAreFatal` aborts the simulated job (panics the
/// rank thread), `ErrorsReturn` propagates the `Result`, `Custom` invokes a
/// user closure first and then returns.
#[derive(Clone)]
pub enum ErrorHandler {
    ErrorsAreFatal,
    ErrorsReturn,
    /// `MPI_ERRORS_ABORT` (MPI 4.0): abort only the local rank.
    ErrorsAbort,
    Custom(std::sync::Arc<dyn Fn(&MpiError) + Send + Sync>),
}

impl fmt::Debug for ErrorHandler {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ErrorHandler::ErrorsAreFatal => f.write_str("ErrorsAreFatal"),
            ErrorHandler::ErrorsReturn => f.write_str("ErrorsReturn"),
            ErrorHandler::ErrorsAbort => f.write_str("ErrorsAbort"),
            ErrorHandler::Custom(_) => f.write_str("Custom(..)"),
        }
    }
}

impl ErrorHandler {
    /// Apply the handler to a result: fatal handlers panic, returning
    /// handlers pass the error through (after invoking the custom hook).
    pub fn handle<T>(&self, result: Result<T>) -> Result<T> {
        match (&result, self) {
            (Err(e), ErrorHandler::ErrorsAreFatal) | (Err(e), ErrorHandler::ErrorsAbort) => {
                panic!("MPI_ERRORS_ARE_FATAL: {e}");
            }
            (Err(e), ErrorHandler::Custom(hook)) => {
                hook(e);
                result
            }
            _ => result,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn class_code_roundtrip() {
        for code in 0..62 {
            let class = ErrorClass::from_code(code);
            assert_eq!(class.code(), code, "class {class:?}");
        }
        assert_eq!(ErrorClass::from_code(9999), ErrorClass::Unknown);
    }

    #[test]
    fn error_display_contains_class_and_message() {
        let e = MpiError::new(ErrorClass::Truncate, "recv buffer 4 < message 16");
        let s = e.to_string();
        assert!(s.contains("truncated"), "{s}");
        assert!(s.contains("recv buffer"), "{s}");
        assert_eq!(e.code(), 15);
    }

    #[test]
    fn errors_return_passes_through() {
        let h = ErrorHandler::ErrorsReturn;
        let r: Result<i32> = Err(MpiError::new(ErrorClass::Tag, "bad tag"));
        assert!(h.handle(r).is_err());
        assert_eq!(h.handle(Ok(3i32)).unwrap(), 3);
    }

    #[test]
    #[should_panic(expected = "MPI_ERRORS_ARE_FATAL")]
    fn errors_fatal_panics() {
        let h = ErrorHandler::ErrorsAreFatal;
        let r: Result<()> = Err(MpiError::new(ErrorClass::Rank, "rank 7 out of range"));
        let _ = h.handle(r);
    }

    #[test]
    fn custom_handler_invoked_then_returns() {
        use std::sync::atomic::{AtomicBool, Ordering};
        use std::sync::Arc;
        let hit = Arc::new(AtomicBool::new(false));
        let hit2 = hit.clone();
        let h = ErrorHandler::Custom(Arc::new(move |_| hit2.store(true, Ordering::SeqCst)));
        let r: Result<()> = Err(MpiError::new(ErrorClass::Count, "negative count"));
        assert!(h.handle(r).is_err());
        assert!(hit.load(Ordering::SeqCst));
    }
}
