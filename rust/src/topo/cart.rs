//! Cartesian topologies (`MPI_Cart_*`).

use crate::comm::{Comm, PROC_NULL};
use crate::{mpi_err, Result};

/// `MPI_Dims_create`: factor `nnodes` into `ndims` balanced dimensions.
/// Zeros in `dims` are free; nonzero entries are constraints.
pub fn dims_create(nnodes: usize, dims: &mut [usize]) -> Result<()> {
    let fixed: usize = dims.iter().filter(|&&d| d > 0).product::<usize>().max(1);
    if nnodes % fixed != 0 {
        return Err(mpi_err!(Dims, "nnodes {nnodes} not divisible by fixed dims product {fixed}"));
    }
    let rem = nnodes / fixed;
    let free: Vec<usize> = (0..dims.len()).filter(|&i| dims[i] == 0).collect();
    if free.is_empty() {
        if rem != 1 {
            return Err(mpi_err!(Dims, "dims fully constrained but product != nnodes"));
        }
        return Ok(());
    }
    // Greedy balanced factorization: repeatedly pull the largest prime
    // factor into the currently smallest dimension.
    let mut vals = vec![1usize; free.len()];
    let mut factors = Vec::new();
    let mut n = rem;
    let mut f = 2;
    while f * f <= n {
        while n % f == 0 {
            factors.push(f);
            n /= f;
        }
        f += 1;
    }
    if n > 1 {
        factors.push(n);
    }
    factors.sort_unstable_by(|a, b| b.cmp(a));
    for f in factors {
        let i = (0..vals.len()).min_by_key(|&i| vals[i]).unwrap();
        vals[i] *= f;
    }
    vals.sort_unstable_by(|a, b| b.cmp(a)); // larger dims first, like MPICH
    for (slot, v) in free.iter().zip(vals) {
        dims[*slot] = v;
    }
    Ok(())
}

/// A communicator with cartesian topology attached.
pub struct CartComm {
    comm: Comm,
    dims: Vec<usize>,
    periods: Vec<bool>,
}

impl CartComm {
    /// `MPI_Cart_create`. Ranks beyond the grid get `None`
    /// (`MPI_COMM_NULL`). `reorder` is accepted but this implementation
    /// keeps the identity mapping (legal: reordering is advisory).
    pub fn create(comm: &Comm, dims: &[usize], periods: &[bool], _reorder: bool) -> Result<Option<CartComm>> {
        if dims.is_empty() || dims.len() != periods.len() {
            return Err(mpi_err!(Dims, "dims/periods must be nonempty and equal length"));
        }
        let total: usize = dims.iter().product();
        if total > comm.size() {
            return Err(mpi_err!(Topology, "grid of {total} exceeds communicator size {}", comm.size()));
        }
        let color = if comm.rank() < total { 0 } else { -1 };
        let sub = comm.split(color, comm.rank() as i32)?;
        Ok(sub.map(|comm| CartComm { comm, dims: dims.to_vec(), periods: periods.to_vec() }))
    }

    pub fn comm(&self) -> &Comm {
        &self.comm
    }

    /// `MPI_Cartdim_get`.
    pub fn ndims(&self) -> usize {
        self.dims.len()
    }

    /// `MPI_Cart_get`.
    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    pub fn periods(&self) -> &[bool] {
        &self.periods
    }

    /// `MPI_Cart_coords` (row-major).
    pub fn coords(&self, rank: usize) -> Result<Vec<usize>> {
        if rank >= self.comm.size() {
            return Err(mpi_err!(Rank, "rank {rank} outside cart comm"));
        }
        let mut c = vec![0usize; self.dims.len()];
        let mut rem = rank;
        for d in (0..self.dims.len()).rev() {
            c[d] = rem % self.dims[d];
            rem /= self.dims[d];
        }
        Ok(c)
    }

    /// `MPI_Cart_rank` (periodic wrap where allowed).
    pub fn rank_of(&self, coords: &[i64]) -> Result<usize> {
        if coords.len() != self.dims.len() {
            return Err(mpi_err!(Dims, "coordinate dimensionality mismatch"));
        }
        let mut rank = 0usize;
        for d in 0..self.dims.len() {
            let n = self.dims[d] as i64;
            let c = if self.periods[d] {
                coords[d].rem_euclid(n)
            } else {
                if coords[d] < 0 || coords[d] >= n {
                    return Err(mpi_err!(Rank, "coordinate {} out of non-periodic dim {d}", coords[d]));
                }
                coords[d]
            };
            rank = rank * self.dims[d] + c as usize;
        }
        Ok(rank)
    }

    /// `MPI_Cart_shift`: (source, dest) for a displacement along `dim`;
    /// `PROC_NULL` at non-periodic edges.
    pub fn shift(&self, dim: usize, disp: i64) -> Result<(i32, i32)> {
        let my = self.coords(self.comm.rank())?;
        let mut up = my.iter().map(|&c| c as i64).collect::<Vec<_>>();
        let mut down = up.clone();
        up[dim] += disp;
        down[dim] -= disp;
        let dest = self.rank_of(&up).map(|r| r as i32).unwrap_or(PROC_NULL);
        let source = self.rank_of(&down).map(|r| r as i32).unwrap_or(PROC_NULL);
        Ok((source, dest))
    }

    /// `MPI_Cart_sub`: keep the dimensions flagged true; one subgrid
    /// communicator per combination of the dropped coordinates.
    pub fn sub(&self, remain: &[bool]) -> Result<CartComm> {
        if remain.len() != self.dims.len() {
            return Err(mpi_err!(Dims, "remain_dims length mismatch"));
        }
        let my = self.coords(self.comm.rank())?;
        // Color = dropped coordinates flattened; key = kept coords
        // flattened (preserves row-major order inside the subgrid).
        let mut color = 0i32;
        let mut key = 0i32;
        for d in 0..self.dims.len() {
            if remain[d] {
                key = key * self.dims[d] as i32 + my[d] as i32;
            } else {
                color = color * self.dims[d] as i32 + my[d] as i32;
            }
        }
        let sub = self
            .comm
            .split(color, key)?
            .ok_or_else(|| mpi_err!(Intern, "cart_sub split yielded null"))?;
        let dims: Vec<usize> =
            (0..self.dims.len()).filter(|&d| remain[d]).map(|d| self.dims[d]).collect();
        let periods: Vec<bool> =
            (0..self.dims.len()).filter(|&d| remain[d]).map(|d| self.periods[d]).collect();
        Ok(CartComm { comm: sub, dims, periods })
    }

    /// Neighbor list in dimension order (-d, +d for each d): what the
    /// cartesian neighborhood collectives iterate (`MPI_Neighbor_*`).
    pub fn neighbors(&self) -> Result<Vec<i32>> {
        let mut out = Vec::with_capacity(2 * self.dims.len());
        for d in 0..self.dims.len() {
            let (src, dst) = self.shift(d, 1)?;
            out.push(src); // -d neighbor
            out.push(dst); // +d neighbor
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dims_create_balances() {
        let mut d = vec![0, 0];
        dims_create(12, &mut d).unwrap();
        assert_eq!(d.iter().product::<usize>(), 12);
        assert_eq!(d, vec![4, 3]);

        let mut d = vec![0, 0, 0];
        dims_create(8, &mut d).unwrap();
        assert_eq!(d, vec![2, 2, 2]);

        let mut d = vec![3, 0];
        dims_create(12, &mut d).unwrap();
        assert_eq!(d, vec![3, 4]);

        let mut d = vec![5, 0];
        assert!(dims_create(12, &mut d).is_err());
    }
}
