//! Graph and distributed-graph topologies (`MPI_Graph_*`,
//! `MPI_Dist_graph_*`).

use crate::comm::Comm;
use crate::{mpi_err, Result};

/// Classic graph topology: full adjacency replicated on every rank
/// (`MPI_Graph_create` with `index`/`edges` arrays).
pub struct GraphComm {
    comm: Comm,
    /// CSR-style: `index[i]` = end of rank i's neighbor list in `edges`.
    index: Vec<usize>,
    edges: Vec<usize>,
}

impl GraphComm {
    pub fn create(comm: &Comm, index: &[usize], edges: &[usize], _reorder: bool) -> Result<Option<GraphComm>> {
        let nnodes = index.len();
        if nnodes == 0 || nnodes > comm.size() {
            return Err(mpi_err!(Topology, "graph nnodes {nnodes} invalid for size {}", comm.size()));
        }
        if index.windows(2).any(|w| w[1] < w[0]) || *index.last().unwrap() != edges.len() {
            return Err(mpi_err!(Arg, "graph index array malformed"));
        }
        if edges.iter().any(|&e| e >= nnodes) {
            return Err(mpi_err!(Rank, "graph edge endpoint out of range"));
        }
        let color = if comm.rank() < nnodes { 0 } else { -1 };
        let sub = comm.split(color, comm.rank() as i32)?;
        Ok(sub.map(|comm| GraphComm { comm, index: index.to_vec(), edges: edges.to_vec() }))
    }

    pub fn comm(&self) -> &Comm {
        &self.comm
    }

    /// `MPI_Graphdims_get`.
    pub fn counts(&self) -> (usize, usize) {
        (self.index.len(), self.edges.len())
    }

    /// `MPI_Graph_neighbors_count` / `MPI_Graph_neighbors`.
    pub fn neighbors_of(&self, rank: usize) -> Result<&[usize]> {
        if rank >= self.index.len() {
            return Err(mpi_err!(Rank, "rank {rank} outside graph"));
        }
        let lo = if rank == 0 { 0 } else { self.index[rank - 1] };
        Ok(&self.edges[lo..self.index[rank]])
    }

    pub fn neighbors(&self) -> Result<&[usize]> {
        self.neighbors_of(self.comm.rank())
    }
}

/// Distributed graph (`MPI_Dist_graph_create_adjacent`): each rank knows
/// only its own in/out neighbor lists.
pub struct DistGraphComm {
    comm: Comm,
    sources: Vec<usize>,
    destinations: Vec<usize>,
}

impl DistGraphComm {
    pub fn create_adjacent(
        comm: &Comm,
        sources: &[usize],
        destinations: &[usize],
        _reorder: bool,
    ) -> Result<DistGraphComm> {
        for &r in sources.iter().chain(destinations) {
            if r >= comm.size() {
                return Err(mpi_err!(Rank, "neighbor {r} outside communicator"));
            }
        }
        Ok(DistGraphComm {
            comm: comm.dup()?,
            sources: sources.to_vec(),
            destinations: destinations.to_vec(),
        })
    }

    pub fn comm(&self) -> &Comm {
        &self.comm
    }

    /// `MPI_Dist_graph_neighbors_count` / `_neighbors`.
    pub fn sources(&self) -> &[usize] {
        &self.sources
    }

    pub fn destinations(&self) -> &[usize] {
        &self.destinations
    }
}
