//! Process topologies (MPI-4.0 §8): cartesian grids, graphs, distributed
//! graphs, and the neighborhood collectives over them.

pub mod cart;
pub mod graph;
pub mod neighborhood;

pub use cart::{dims_create, CartComm};
pub use graph::{DistGraphComm, GraphComm};
