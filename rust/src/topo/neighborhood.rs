//! Neighborhood collectives (MPI-4.0 §8.9): allgather/alltoall over the
//! topology's neighbor lists, expressed directly on nonblocking p2p (each
//! is one shot of isends+irecvs with a reserved tag).

use crate::comm::{Comm, PROC_NULL};
use crate::datatype::Datatype;
use crate::request::wait_all;
use crate::Result;

const NEIGHBOR_TAG: i32 = crate::comm::TAG_UB - 3;

/// Generic engine: send `sbuf` to every destination, receive one block per
/// source into `rbuf` (block i ← sources[i]).
pub fn neighbor_allgather_lists(
    comm: &Comm,
    sources: &[i32],
    destinations: &[i32],
    sbuf: &[u8],
    scount: usize,
    sdtype: &Datatype,
    rbuf: &mut [u8],
    rcount: usize,
    rdtype: &Datatype,
) -> Result<()> {
    let block = rcount * rdtype.extent() as usize;
    let mut reqs = Vec::with_capacity(sources.len() + destinations.len());
    // Receives first (block per source, in list order).
    let mut rest = rbuf;
    for &src in sources {
        let (head, tail) = rest.split_at_mut(block.min(rest.len()));
        rest = tail;
        if src == PROC_NULL {
            continue;
        }
        reqs.push(comm.irecv(head, rcount, rdtype, src, NEIGHBOR_TAG)?);
    }
    for &dst in destinations {
        if dst == PROC_NULL {
            continue;
        }
        reqs.push(comm.isend(sbuf, scount, sdtype, dst, NEIGHBOR_TAG)?);
    }
    wait_all(&reqs)?;
    Ok(())
}

/// Generic engine: distinct block per destination (alltoall flavor).
#[allow(clippy::too_many_arguments)]
pub fn neighbor_alltoall_lists(
    comm: &Comm,
    sources: &[i32],
    destinations: &[i32],
    sbuf: &[u8],
    scount: usize,
    sdtype: &Datatype,
    rbuf: &mut [u8],
    rcount: usize,
    rdtype: &Datatype,
) -> Result<()> {
    let sblock = scount * sdtype.extent() as usize;
    let rblock = rcount * rdtype.extent() as usize;
    let mut reqs = Vec::with_capacity(sources.len() + destinations.len());
    let mut rest = rbuf;
    for &src in sources {
        let (head, tail) = rest.split_at_mut(rblock.min(rest.len()));
        rest = tail;
        if src == PROC_NULL {
            continue;
        }
        reqs.push(comm.irecv(head, rcount, rdtype, src, NEIGHBOR_TAG)?);
    }
    for (i, &dst) in destinations.iter().enumerate() {
        if dst == PROC_NULL {
            continue;
        }
        let lo = i * sblock;
        reqs.push(comm.isend(&sbuf[lo..lo + sblock], scount, sdtype, dst, NEIGHBOR_TAG)?);
    }
    wait_all(&reqs)?;
    Ok(())
}

impl super::CartComm {
    /// `MPI_Neighbor_allgather` on a cartesian grid: one block per
    /// neighbor in (-d, +d) dimension order; PROC_NULL edges leave their
    /// block untouched.
    pub fn neighbor_allgather(
        &self,
        sbuf: &[u8],
        scount: usize,
        sdtype: &Datatype,
        rbuf: &mut [u8],
        rcount: usize,
        rdtype: &Datatype,
    ) -> Result<()> {
        let n = self.neighbors()?;
        neighbor_allgather_lists(self.comm(), &n, &n, sbuf, scount, sdtype, rbuf, rcount, rdtype)
    }

    /// `MPI_Neighbor_alltoall` on a cartesian grid (the halo-exchange
    /// primitive: block i of the send buffer goes to neighbor i).
    pub fn neighbor_alltoall(
        &self,
        sbuf: &[u8],
        scount: usize,
        sdtype: &Datatype,
        rbuf: &mut [u8],
        rcount: usize,
        rdtype: &Datatype,
    ) -> Result<()> {
        let n = self.neighbors()?;
        neighbor_alltoall_lists(self.comm(), &n, &n, sbuf, scount, sdtype, rbuf, rcount, rdtype)
    }
}

impl super::DistGraphComm {
    /// `MPI_Neighbor_allgather` over explicit adjacency.
    pub fn neighbor_allgather(
        &self,
        sbuf: &[u8],
        scount: usize,
        sdtype: &Datatype,
        rbuf: &mut [u8],
        rcount: usize,
        rdtype: &Datatype,
    ) -> Result<()> {
        let src: Vec<i32> = self.sources().iter().map(|&r| r as i32).collect();
        let dst: Vec<i32> = self.destinations().iter().map(|&r| r as i32).collect();
        neighbor_allgather_lists(self.comm(), &src, &dst, sbuf, scount, sdtype, rbuf, rcount, rdtype)
    }

    /// `MPI_Neighbor_alltoall` over explicit adjacency.
    pub fn neighbor_alltoall(
        &self,
        sbuf: &[u8],
        scount: usize,
        sdtype: &Datatype,
        rbuf: &mut [u8],
        rcount: usize,
        rdtype: &Datatype,
    ) -> Result<()> {
        let src: Vec<i32> = self.sources().iter().map(|&r| r as i32).collect();
        let dst: Vec<i32> = self.destinations().iter().map(|&r| r as i32).collect();
        neighbor_alltoall_lists(self.comm(), &src, &dst, sbuf, scount, sdtype, rbuf, rcount, rdtype)
    }
}
