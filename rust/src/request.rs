//! Request objects and the completion family (MPI-4.0 §3.7):
//! test/wait/{all,any,some}, persistent requests, generalized requests.
//!
//! A [`Request`] becomes the *null request* after it completes (its status
//! has been taken), mirroring `MPI_REQUEST_NULL` semantics: completed
//! entries in `wait_all`/`wait_any` arrays are skipped.

use crate::datatype::Datatype;
use crate::group::Group;
use crate::p2p::{self, engine, RankCtx, RawBuf, RawBufMut, SendMode, Status};
use crate::{mpi_err, Result};
use std::cell::RefCell;
use std::rc::Rc;

/// Completion source for composite operations (nonblocking collectives,
/// collective IO, generalized requests). The operation itself progresses
/// via [`p2p::Progressable`]; this trait only reports/extracts completion.
pub trait CustomRequest {
    fn done(&self) -> bool;
    /// Take the final status; called exactly once, after `done()`.
    fn take_status(&self) -> Result<Status>;
}

enum ReqKind {
    Send(u64),
    Recv(u64),
    Ready(Status),
    Custom(Rc<dyn CustomRequest>),
    Null,
}

/// An `MPI_Request`.
pub struct Request {
    ctx: Rc<RankCtx>,
    kind: RefCell<ReqKind>,
}

impl Request {
    pub fn from_send(ctx: Rc<RankCtx>, token: Option<u64>) -> Request {
        let kind = match token {
            Some(t) => ReqKind::Send(t),
            None => ReqKind::Ready(Status::empty()),
        };
        Request { ctx, kind: RefCell::new(kind) }
    }

    pub fn from_recv(ctx: Rc<RankCtx>, token: u64) -> Request {
        Request { ctx, kind: RefCell::new(ReqKind::Recv(token)) }
    }

    /// Completed-at-creation (PROC_NULL ops, zero-size fast paths).
    pub fn ready(ctx: Rc<RankCtx>, status: Status) -> Request {
        Request { ctx, kind: RefCell::new(ReqKind::Ready(status)) }
    }

    pub fn custom(ctx: Rc<RankCtx>, c: Rc<dyn CustomRequest>) -> Request {
        Request { ctx, kind: RefCell::new(ReqKind::Custom(c)) }
    }

    /// `MPI_REQUEST_NULL`.
    pub fn null(ctx: Rc<RankCtx>) -> Request {
        Request { ctx, kind: RefCell::new(ReqKind::Null) }
    }

    pub fn is_null(&self) -> bool {
        matches!(*self.kind.borrow(), ReqKind::Null)
    }

    pub fn rank_ctx(&self) -> &Rc<RankCtx> {
        &self.ctx
    }

    /// Non-consuming readiness check (no progress driven).
    fn ready_now(&self) -> bool {
        match &*self.kind.borrow() {
            ReqKind::Send(t) => engine::send_done(&self.ctx, *t),
            ReqKind::Recv(t) => engine::recv_done(&self.ctx, *t),
            ReqKind::Ready(_) => true,
            ReqKind::Custom(c) => c.done(),
            ReqKind::Null => true,
        }
    }

    /// Consume the completion, transitioning to the null request.
    fn consume(&self) -> Result<Status> {
        let kind = std::mem::replace(&mut *self.kind.borrow_mut(), ReqKind::Null);
        match kind {
            ReqKind::Send(t) => {
                engine::take_send_done(&self.ctx, t);
                Ok(Status::empty())
            }
            ReqKind::Recv(t) => engine::take_recv_result(&self.ctx, t)
                .ok_or_else(|| mpi_err!(Intern, "consume of incomplete recv"))?,
            ReqKind::Ready(s) => Ok(s),
            ReqKind::Custom(c) => c.take_status(),
            ReqKind::Null => Ok(Status::empty()),
        }
    }

    /// Non-consuming, non-progressing readiness check (used by composite
    /// waiters like `when_any` that must not steal completions).
    pub fn test_ready_nonconsuming(&self) -> bool {
        self.ready_now()
    }

    /// `MPI_Test`: drives progress once; returns the status if complete.
    pub fn test(&self) -> Result<Option<Status>> {
        if self.is_null() {
            return Ok(Some(Status::empty()));
        }
        engine::progress(&self.ctx)?;
        if self.ready_now() {
            Ok(Some(self.consume()?))
        } else {
            Ok(None)
        }
    }

    /// `MPI_Wait`.
    pub fn wait(&self) -> Result<Status> {
        if self.is_null() {
            return Ok(Status::empty());
        }
        engine::wait_for(&self.ctx, || self.ready_now())?;
        self.consume()
    }

    /// Error-path cleanup when the caller can no longer guarantee the
    /// operation's buffer: a send whose rendezvous packing was deferred
    /// is staged while the buffer is still live
    /// ([`engine::detach_deferred_send`]); a still-registered receive is
    /// abandoned ([`engine::abandon_recv`]) so a late delivery fails
    /// instead of writing through a dangling pointer. Call before letting
    /// the buffer of an incomplete operation go. No-op otherwise.
    pub fn detach_buffers(&self) {
        match &*self.kind.borrow() {
            ReqKind::Send(t) => engine::detach_deferred_send(&self.ctx, *t),
            ReqKind::Recv(t) => engine::abandon_recv(&self.ctx, *t),
            _ => {}
        }
    }
}

impl std::fmt::Debug for Request {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let k = match &*self.kind.borrow() {
            ReqKind::Send(t) => format!("send#{t}"),
            ReqKind::Recv(t) => format!("recv#{t}"),
            ReqKind::Ready(_) => "ready".into(),
            ReqKind::Custom(_) => "custom".into(),
            ReqKind::Null => "null".into(),
        };
        write!(f, "Request({k})")
    }
}

/// `MPI_Waitall`.
pub fn wait_all(reqs: &[Request]) -> Result<Vec<Status>> {
    if reqs.is_empty() {
        return Ok(Vec::new());
    }
    let ctx = reqs[0].ctx.clone();
    engine::wait_for(&ctx, || reqs.iter().all(|r| r.ready_now()))?;
    reqs.iter().map(|r| if r.is_null() { Ok(Status::empty()) } else { r.consume() }).collect()
}

/// `MPI_Waitany`: index of the completed request and its status. All-null
/// input returns `None` (the standard's `MPI_UNDEFINED`).
pub fn wait_any(reqs: &[Request]) -> Result<Option<(usize, Status)>> {
    if reqs.is_empty() || reqs.iter().all(|r| r.is_null()) {
        return Ok(None);
    }
    let ctx = reqs[0].ctx.clone();
    engine::wait_for(&ctx, || reqs.iter().any(|r| !r.is_null() && r.ready_now()))?;
    let idx = reqs.iter().position(|r| !r.is_null() && r.ready_now()).unwrap();
    Ok(Some((idx, reqs[idx].consume()?)))
}

/// `MPI_Waitsome`: indices + statuses of everything complete once at least
/// one is.
pub fn wait_some(reqs: &[Request]) -> Result<Vec<(usize, Status)>> {
    if reqs.is_empty() || reqs.iter().all(|r| r.is_null()) {
        return Ok(Vec::new());
    }
    let ctx = reqs[0].ctx.clone();
    engine::wait_for(&ctx, || reqs.iter().any(|r| !r.is_null() && r.ready_now()))?;
    let mut out = Vec::new();
    for (i, r) in reqs.iter().enumerate() {
        if !r.is_null() && r.ready_now() {
            out.push((i, r.consume()?));
        }
    }
    Ok(out)
}

/// `MPI_Testall`.
pub fn test_all(reqs: &[Request]) -> Result<Option<Vec<Status>>> {
    if reqs.is_empty() {
        return Ok(Some(Vec::new()));
    }
    engine::progress(&reqs[0].ctx)?;
    if reqs.iter().all(|r| r.ready_now()) {
        Ok(Some(
            reqs.iter()
                .map(|r| if r.is_null() { Ok(Status::empty()) } else { r.consume() })
                .collect::<Result<_>>()?,
        ))
    } else {
        Ok(None)
    }
}

/// `MPI_Testany`.
pub fn test_any(reqs: &[Request]) -> Result<Option<(usize, Status)>> {
    if reqs.is_empty() {
        return Ok(None);
    }
    engine::progress(&reqs[0].ctx)?;
    for (i, r) in reqs.iter().enumerate() {
        if !r.is_null() && r.ready_now() {
            return Ok(Some((i, r.consume()?)));
        }
    }
    Ok(None)
}

// ---------------- persistent requests (§3.9) ----------------

enum PersistentSpec {
    Send { ctx_id: u32, dst_world: Option<usize>, tag: i32, buf: RawBuf, count: usize, dtype: Datatype, mode: SendMode },
    Recv { ctx_id: u32, src_world: Option<usize>, tag: Option<i32>, buf: RawBufMut, count: usize, dtype: Datatype, group: Group },
}

/// `MPI_Send_init` / `MPI_Recv_init` product: a reusable operation
/// template. `start()` activates it; completing the active request leaves
/// the template reusable.
pub struct PersistentRequest {
    ctx: Rc<RankCtx>,
    spec: PersistentSpec,
    active: RefCell<Option<Request>>,
}

impl PersistentRequest {
    pub fn send_init(
        ctx: Rc<RankCtx>,
        ctx_id: u32,
        dst_world: Option<usize>,
        tag: i32,
        buf: RawBuf,
        count: usize,
        dtype: Datatype,
        mode: SendMode,
    ) -> PersistentRequest {
        PersistentRequest {
            ctx,
            spec: PersistentSpec::Send { ctx_id, dst_world, tag, buf, count, dtype, mode },
            active: RefCell::new(None),
        }
    }

    pub fn recv_init(
        ctx: Rc<RankCtx>,
        ctx_id: u32,
        src_world: Option<usize>,
        tag: Option<i32>,
        buf: RawBufMut,
        count: usize,
        dtype: Datatype,
        group: Group,
    ) -> PersistentRequest {
        PersistentRequest {
            ctx,
            spec: PersistentSpec::Recv { ctx_id, src_world, tag, buf, count, dtype, group },
            active: RefCell::new(None),
        }
    }

    pub fn is_active(&self) -> bool {
        self.active.borrow().as_ref().map(|r| !r.is_null()).unwrap_or(false)
    }

    /// `MPI_Start`.
    pub fn start(&self) -> Result<()> {
        if self.is_active() {
            return Err(mpi_err!(Request, "MPI_Start on an already active persistent request"));
        }
        let req = match &self.spec {
            PersistentSpec::Send { ctx_id, dst_world, tag, buf, count, dtype, mode } => {
                match dst_world {
                    None => Request::ready(self.ctx.clone(), Status::empty()), // PROC_NULL
                    Some(dst) => {
                        let token = engine::start_send(
                            &self.ctx,
                            p2p::SendParams {
                                ctx_id: *ctx_id,
                                dst_world: *dst,
                                tag: *tag,
                                buf: unsafe { buf.as_slice() },
                                count: *count,
                                dtype,
                                mode: *mode,
                                // The registered buffer outlives the
                                // template and stays untouched while
                                // active: safe to pack at CTS time.
                                staging: p2p::RndvStaging::Deferred,
                            },
                        )?;
                        Request::from_send(self.ctx.clone(), token)
                    }
                }
            }
            PersistentSpec::Recv { ctx_id, src_world, tag, buf, count, dtype, group } => {
                let token = engine::post_recv(
                    &self.ctx,
                    *ctx_id,
                    *src_world,
                    *tag,
                    *buf,
                    *count,
                    dtype.clone(),
                    group.clone(),
                )?;
                Request::from_recv(self.ctx.clone(), token)
            }
        };
        *self.active.borrow_mut() = Some(req);
        Ok(())
    }

    /// Wait on the active operation; the template stays reusable.
    /// Inactive templates (never started, or already completed) are a
    /// `Request`-class error, matching the persistent-collective side —
    /// a silent `Ok` here would mask double-complete bugs.
    pub fn wait(&self) -> Result<Status> {
        if !self.is_active() {
            return Err(mpi_err!(Request, "wait on inactive persistent request"));
        }
        let active = self.active.borrow();
        match &*active {
            Some(r) => r.wait(),
            None => unreachable!("is_active implies an active request"),
        }
    }

    pub fn test(&self) -> Result<Option<Status>> {
        if !self.is_active() {
            return Err(mpi_err!(Request, "test on inactive persistent request"));
        }
        let active = self.active.borrow();
        match &*active {
            Some(r) => r.test(),
            None => unreachable!("is_active implies an active request"),
        }
    }
}

impl Drop for PersistentRequest {
    /// Dropping an active template blocks until the in-flight operation
    /// completes: an active receive holds a raw pointer into the
    /// registered buffer, so the engine must not keep delivering into it
    /// after the template (and possibly the buffer) is gone. Skipped
    /// while unwinding — the watchdog panicking inside drop would abort
    /// and mask the original error, and the engine only runs on this
    /// (dying) thread anyway.
    fn drop(&mut self) {
        if self.is_active() && !std::thread::panicking() && self.wait().is_err() {
            // The registered buffer dies with this template; if the
            // rescue wait failed, stage a still-parked deferred payload /
            // abandon a still-registered receive while the buffer lives.
            if let Some(req) = &*self.active.borrow() {
                req.detach_buffers();
            }
        }
    }
}

/// `MPI_Startall`.
pub fn start_all(reqs: &[PersistentRequest]) -> Result<()> {
    for r in reqs {
        r.start()?;
    }
    Ok(())
}

// ---------------- generalized requests (§3.8 ext) ----------------

/// A generalized request's completion side, held by the operation's
/// implementor; `complete()` marks the request done.
#[derive(Debug, Default)]
pub struct GrequestState {
    done: RefCell<Option<Status>>,
}

impl GrequestState {
    pub fn complete(&self, status: Status) {
        *self.done.borrow_mut() = Some(status);
    }
}

impl CustomRequest for GrequestState {
    fn done(&self) -> bool {
        self.done.borrow().is_some()
    }

    fn take_status(&self) -> Result<Status> {
        self.done.borrow_mut().take().ok_or_else(|| mpi_err!(Intern, "grequest not complete"))
    }
}

/// `MPI_Grequest_start`: returns the request and the completion handle.
pub fn grequest_start(ctx: Rc<RankCtx>) -> (Request, Rc<GrequestState>) {
    let st = Rc::new(GrequestState::default());
    (Request::custom(ctx, st.clone()), st)
}
