//! File handles (§14.2): open/close/delete, read/write at explicit
//! offsets, individual and shared file pointers, collective and ordered
//! variants, nonblocking wrappers.

use super::view::View;
use crate::collective;
use crate::comm::Comm;
use crate::datatype::{pack, unpack, Datatype, Primitive};
use crate::op::Op;
use crate::request::{grequest_start, Request};
use crate::transport::fabric::FileNode;
use crate::{mpi_err, ErrorClass, MpiError, Result};
use std::cell::{Cell, RefCell};
use std::sync::atomic::Ordering;
use std::sync::Arc;

/// `MPI_MODE_*` access-mode flags.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct AccessMode {
    pub rdonly: bool,
    pub wronly: bool,
    pub rdwr: bool,
    pub create: bool,
    pub excl: bool,
    pub append: bool,
    pub delete_on_close: bool,
}

impl AccessMode {
    pub fn read() -> AccessMode {
        AccessMode { rdonly: true, ..Default::default() }
    }

    pub fn write() -> AccessMode {
        AccessMode { wronly: true, create: true, ..Default::default() }
    }

    pub fn read_write() -> AccessMode {
        AccessMode { rdwr: true, create: true, ..Default::default() }
    }

    pub fn with_excl(mut self) -> AccessMode {
        self.excl = true;
        self
    }

    pub fn with_append(mut self) -> AccessMode {
        self.append = true;
        self
    }

    pub fn with_delete_on_close(mut self) -> AccessMode {
        self.delete_on_close = true;
        self
    }

    fn validate(&self) -> Result<()> {
        let n = [self.rdonly, self.wronly, self.rdwr].iter().filter(|&&b| b).count();
        if n != 1 {
            return Err(mpi_err!(Amode, "exactly one of RDONLY/WRONLY/RDWR required"));
        }
        if self.rdonly && (self.create || self.excl || self.append) {
            return Err(mpi_err!(Amode, "RDONLY is incompatible with CREATE/EXCL/APPEND"));
        }
        Ok(())
    }

    pub fn can_read(&self) -> bool {
        self.rdonly || self.rdwr
    }

    pub fn can_write(&self) -> bool {
        self.wronly || self.rdwr
    }
}

/// `MPI_File`.
pub struct File {
    comm: Comm,
    node: Arc<FileNode>,
    path: String,
    amode: AccessMode,
    view: RefCell<View>,
    /// Individual file pointer, in *logical view bytes*.
    ptr: Cell<u64>,
    atomicity: Cell<bool>,
}

impl std::fmt::Debug for File {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("File")
            .field("path", &self.path)
            .field("amode", &self.amode)
            .field("ptr", &self.ptr.get())
            .finish_non_exhaustive()
    }
}

impl File {
    /// `MPI_File_open` — collective over `comm`.
    pub fn open(comm: &Comm, path: &str, amode: AccessMode) -> Result<File> {
        amode.validate()?;
        if comm.rank_ctx().fabric.is_multiprocess() {
            // The simulated parallel filesystem lives in process memory;
            // a launched job would give every rank a private disconnected
            // "shared" file. Refuse cleanly instead.
            return Err(mpi_err!(
                Io,
                "the simulated shared filesystem is per-process — MPI-IO is \
                 unavailable on multi-process transport backends"
            ));
        }
        let comm = comm.dup()?;
        let fabric = comm.rank_ctx().fabric.clone();
        // Rank 0 performs the filesystem transaction; the outcome is
        // broadcast so every rank agrees.
        let mut code = [0u8; 4];
        if comm.rank() == 0 {
            let mut files = fabric.files.lock().unwrap();
            let exists = files.contains_key(path);
            let c: i32 = if exists && amode.excl {
                ErrorClass::FileExists.code()
            } else if !exists && !amode.create {
                ErrorClass::NoSuchFile.code()
            } else {
                files.entry(path.to_string()).or_default();
                0
            };
            code.copy_from_slice(&c.to_le_bytes());
        }
        let i32t = Datatype::primitive(Primitive::I32);
        collective::bcast(&comm, &mut code, 1, &i32t, 0)?;
        let code = i32::from_le_bytes(code);
        if code != 0 {
            return Err(MpiError::new(ErrorClass::from_code(code), format!("open '{path}'")));
        }
        let node = fabric.files.lock().unwrap().get(path).unwrap().clone();
        node.open_count.fetch_add(1, Ordering::SeqCst);
        let f = File {
            comm,
            node,
            path: path.to_string(),
            amode,
            view: RefCell::new(View::default()),
            ptr: Cell::new(0),
            atomicity: Cell::new(false),
        };
        if amode.append {
            f.ptr.set(f.size()? as u64);
        }
        Ok(f)
    }

    /// `MPI_File_delete` (non-collective, any rank).
    pub fn delete(comm: &Comm, path: &str) -> Result<()> {
        let fabric = comm.rank_ctx().fabric.clone();
        let mut files = fabric.files.lock().unwrap();
        match files.get(path) {
            None => Err(mpi_err!(NoSuchFile, "delete '{path}'")),
            Some(node) if node.open_count.load(Ordering::SeqCst) > 0 => {
                Err(mpi_err!(FileInUse, "delete '{path}' while open"))
            }
            Some(_) => {
                files.remove(path);
                Ok(())
            }
        }
    }

    /// `MPI_File_close` — collective; honors delete-on-close.
    pub fn close(self) -> Result<()> {
        collective::barrier(&self.comm)?;
        let remaining = self.node.open_count.fetch_sub(1, Ordering::SeqCst) - 1;
        if self.amode.delete_on_close && remaining == 0 && self.comm.rank() == 0 {
            self.comm.rank_ctx().fabric.files.lock().unwrap().remove(&self.path);
        }
        Ok(())
    }

    pub fn amode(&self) -> AccessMode {
        self.amode
    }

    pub fn comm(&self) -> &Comm {
        &self.comm
    }

    /// `MPI_File_get_size` (physical bytes).
    pub fn size(&self) -> Result<usize> {
        Ok(self.node.data.lock().unwrap().len())
    }

    /// `MPI_File_set_size` (truncate or zero-extend); collective. The
    /// leading barrier keeps the resize from racing reads other ranks
    /// issue before entering the call.
    pub fn set_size(&self, size: usize) -> Result<()> {
        collective::barrier(&self.comm)?;
        if self.comm.rank() == 0 {
            self.node.data.lock().unwrap().resize(size, 0);
        }
        collective::barrier(&self.comm)
    }

    /// `MPI_File_preallocate`.
    pub fn preallocate(&self, size: usize) -> Result<()> {
        collective::barrier(&self.comm)?;
        if self.comm.rank() == 0 {
            let mut d = self.node.data.lock().unwrap();
            if d.len() < size {
                d.resize(size, 0);
            }
        }
        collective::barrier(&self.comm)
    }

    /// `MPI_File_set_view` — collective.
    pub fn set_view(&self, displacement: u64, etype: &Datatype, filetype: &Datatype) -> Result<()> {
        let v = View::new(displacement, etype.clone(), filetype.clone())?;
        *self.view.borrow_mut() = v;
        self.ptr.set(0);
        if self.comm.rank() == 0 {
            *self.node.shared_ptr.lock().unwrap() = 0;
        }
        collective::barrier(&self.comm)
    }

    /// `MPI_File_get_view`.
    pub fn view(&self) -> View {
        self.view.borrow().clone()
    }

    /// `MPI_File_set_atomicity` / `get_atomicity`.
    pub fn set_atomicity(&self, on: bool) {
        self.atomicity.set(on);
    }

    pub fn atomicity(&self) -> bool {
        self.atomicity.get()
    }

    /// `MPI_File_sync` (the in-memory store is always durable; this is a
    /// collective ordering point).
    pub fn sync(&self) -> Result<()> {
        collective::barrier(&self.comm)
    }

    // ---- explicit-offset ops (§14.4.2) ----

    /// `MPI_File_read_at`: `offset` is in etypes. Returns elements read.
    pub fn read_at(&self, offset: u64, buf: &mut [u8], count: usize, dtype: &Datatype) -> Result<usize> {
        if !self.amode.can_read() {
            return Err(mpi_err!(Amode, "file not opened for reading"));
        }
        dtype.require_committed()?;
        let view = self.view.borrow();
        let lo = offset * view.etype.size() as u64;
        let nbytes = dtype.size() * count;
        let mut wire = vec![0u8; nbytes];
        let got = {
            let data = self.node.data.lock().unwrap();
            view.read(&data, lo, &mut wire)
        };
        let whole = got / dtype.size().max(1);
        unpack(dtype.map(), &wire[..whole * dtype.size()], buf, whole)?;
        Ok(whole)
    }

    /// `MPI_File_write_at`. Returns elements written.
    pub fn write_at(&self, offset: u64, buf: &[u8], count: usize, dtype: &Datatype) -> Result<usize> {
        if !self.amode.can_write() {
            return Err(mpi_err!(Amode, "file not opened for writing"));
        }
        dtype.require_committed()?;
        let view = self.view.borrow();
        let lo = offset * view.etype.size() as u64;
        let mut wire = Vec::with_capacity(dtype.size() * count);
        pack(dtype.map(), buf, count, &mut wire)?;
        {
            let mut data = self.node.data.lock().unwrap();
            view.write(&mut data, lo, &wire);
        }
        Ok(count)
    }

    /// `MPI_File_read_at_all` / `write_at_all`: collective versions (the
    /// in-memory store needs no two-phase aggregation; the collective
    /// contract — all ranks arrive — is enforced with a barrier).
    pub fn read_at_all(&self, offset: u64, buf: &mut [u8], count: usize, dtype: &Datatype) -> Result<usize> {
        let n = self.read_at(offset, buf, count, dtype)?;
        collective::barrier(&self.comm)?;
        Ok(n)
    }

    pub fn write_at_all(&self, offset: u64, buf: &[u8], count: usize, dtype: &Datatype) -> Result<usize> {
        let n = self.write_at(offset, buf, count, dtype)?;
        collective::barrier(&self.comm)?;
        Ok(n)
    }

    // ---- individual file pointer (§14.4.3) ----

    /// `MPI_File_seek` (whence = set).
    pub fn seek(&self, offset_etypes: u64) {
        self.ptr.set(offset_etypes);
    }

    /// `MPI_File_get_position` (etypes).
    pub fn position(&self) -> u64 {
        self.ptr.get()
    }

    /// `MPI_File_read`.
    pub fn read(&self, buf: &mut [u8], count: usize, dtype: &Datatype) -> Result<usize> {
        let n = self.read_at(self.ptr.get(), buf, count, dtype)?;
        let esz = self.view.borrow().etype.size().max(1);
        self.ptr.set(self.ptr.get() + (n * dtype.size() / esz) as u64);
        Ok(n)
    }

    /// `MPI_File_write`.
    pub fn write(&self, buf: &[u8], count: usize, dtype: &Datatype) -> Result<usize> {
        let n = self.write_at(self.ptr.get(), buf, count, dtype)?;
        let esz = self.view.borrow().etype.size().max(1);
        self.ptr.set(self.ptr.get() + (n * dtype.size() / esz) as u64);
        Ok(n)
    }

    /// `MPI_File_read_all` / `write_all`.
    pub fn read_all(&self, buf: &mut [u8], count: usize, dtype: &Datatype) -> Result<usize> {
        let n = self.read(buf, count, dtype)?;
        collective::barrier(&self.comm)?;
        Ok(n)
    }

    pub fn write_all(&self, buf: &[u8], count: usize, dtype: &Datatype) -> Result<usize> {
        let n = self.write(buf, count, dtype)?;
        collective::barrier(&self.comm)?;
        Ok(n)
    }

    // ---- shared file pointer (§14.4.4) ----

    fn bump_shared(&self, etypes: u64) -> u64 {
        let mut p = self.node.shared_ptr.lock().unwrap();
        let at = *p;
        *p += etypes;
        at
    }

    /// `MPI_File_read_shared`.
    pub fn read_shared(&self, buf: &mut [u8], count: usize, dtype: &Datatype) -> Result<usize> {
        let esz = self.view.borrow().etype.size().max(1);
        let at = self.bump_shared((dtype.size() * count / esz) as u64);
        self.read_at(at, buf, count, dtype)
    }

    /// `MPI_File_write_shared`.
    pub fn write_shared(&self, buf: &[u8], count: usize, dtype: &Datatype) -> Result<usize> {
        let esz = self.view.borrow().etype.size().max(1);
        let at = self.bump_shared((dtype.size() * count / esz) as u64);
        self.write_at(at, buf, count, dtype)
    }

    /// `MPI_File_write_ordered`: rank-order offsets via exscan of sizes.
    pub fn write_ordered(&self, buf: &[u8], count: usize, dtype: &Datatype) -> Result<usize> {
        let esz = self.view.borrow().etype.size().max(1);
        let mine = (dtype.size() * count / esz) as u64;
        let base = {
            let p = self.node.shared_ptr.lock().unwrap();
            *p
        };
        let u64t = Datatype::primitive(Primitive::U64);
        let mut before = [0u8; 8];
        collective::exscan(&self.comm, Some(&mine.to_le_bytes()), &mut before, 1, &u64t, &Op::SUM)?;
        let before = if self.comm.rank() == 0 { 0 } else { u64::from_le_bytes(before) };
        let n = self.write_at(base + before, buf, count, dtype)?;
        // Advance the shared pointer past everyone (rank 0, after barrier).
        let mut total = [0u8; 8];
        collective::allreduce(&self.comm, Some(&mine.to_le_bytes()), &mut total, 1, &u64t, &Op::SUM)?;
        if self.comm.rank() == 0 {
            *self.node.shared_ptr.lock().unwrap() = base + u64::from_le_bytes(total);
        }
        collective::barrier(&self.comm)?;
        Ok(n)
    }

    // ---- nonblocking (§14.4.5): performed eagerly, completion via
    // generalized request (legal: "nonblocking" bounds completion, not
    // initiation). ----

    /// `MPI_File_iread_at`.
    pub fn iread_at(&self, offset: u64, buf: &mut [u8], count: usize, dtype: &Datatype) -> Result<Request> {
        let n = self.read_at(offset, buf, count, dtype)?;
        let (req, done) = grequest_start(self.comm.rank_ctx().clone());
        done.complete(crate::p2p::Status { source: 0, tag: 0, bytes: n * dtype.size(), cancelled: false });
        Ok(req)
    }

    /// `MPI_File_iwrite_at`.
    pub fn iwrite_at(&self, offset: u64, buf: &[u8], count: usize, dtype: &Datatype) -> Result<Request> {
        let n = self.write_at(offset, buf, count, dtype)?;
        let (req, done) = grequest_start(self.comm.rank_ctx().clone());
        done.complete(crate::p2p::Status { source: 0, tag: 0, bytes: n * dtype.size(), cancelled: false });
        Ok(req)
    }
}
