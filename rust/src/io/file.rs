//! File handles (§14.2): open/close/delete, read/write at explicit
//! offsets, individual and shared file pointers, collective (including
//! split) and ordered variants, and nonblocking operations returning
//! first-class [`Request`]s.
//!
//! Every operation is transport traffic: the client injects an `Io*`
//! packet toward the file server rank ([`server_rank`]) and waits on (or
//! hands the caller a request for) the origin-side completion token —
//! exactly the RMA pattern. Blocking calls drive the engine with
//! [`wait_for`]; nonblocking ones wrap the token in a [`CustomRequest`].
//! Collective writes route through the two-phase exchange
//! ([`CollectiveWrite`]) when enabled (`FERROMPI_IO_TWOPHASE`, default
//! on; [`File::set_twophase`] overrides per handle — collectively, all
//! ranks must agree).
//!
//! On launched (`shm`/`socket`) backends the one real filesystem lives
//! in world rank 0's process and every packet crosses the wire to it;
//! set `FERROMPI_IO_SERVER=0` to disable the served path, in which case
//! `File::open` refuses cleanly on multi-process backends.

use super::server::{
    self, server_rank, FLAG_CREATE, FLAG_DELETE_ON_CLOSE, FLAG_EXCL, OP_CLOSE, OP_DELETE, OP_OPEN,
    OP_PREALLOC, OP_SET_SIZE, OP_SHARED_BUMP, OP_SHARED_GET, OP_SHARED_SET, OP_SIZE,
};
use super::twophase::{twophase_default, CollectiveWrite};
use super::view::View;
use crate::collective;
use crate::comm::Comm;
use crate::datatype::{pack, unpack, Datatype, Primitive, TypeMap};
use crate::op::Op;
use crate::p2p::{
    io_done, start_io, take_io_result, wait_for, IoKind, RankCtx, RawBufMut, Status,
};
use crate::request::{CustomRequest, Request};
use crate::transport::WireBytes;
use crate::{mpi_err, ErrorClass, MpiError, Result};
use std::cell::{Cell, RefCell};
use std::rc::Rc;
use std::sync::Arc;

/// `MPI_MODE_*` access-mode flags.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct AccessMode {
    pub rdonly: bool,
    pub wronly: bool,
    pub rdwr: bool,
    pub create: bool,
    pub excl: bool,
    pub append: bool,
    pub delete_on_close: bool,
}

impl AccessMode {
    pub fn read() -> AccessMode {
        AccessMode { rdonly: true, ..Default::default() }
    }

    pub fn write() -> AccessMode {
        AccessMode { wronly: true, create: true, ..Default::default() }
    }

    pub fn read_write() -> AccessMode {
        AccessMode { rdwr: true, create: true, ..Default::default() }
    }

    pub fn with_excl(mut self) -> AccessMode {
        self.excl = true;
        self
    }

    pub fn with_append(mut self) -> AccessMode {
        self.append = true;
        self
    }

    pub fn with_delete_on_close(mut self) -> AccessMode {
        self.delete_on_close = true;
        self
    }

    fn validate(&self) -> Result<()> {
        let n = [self.rdonly, self.wronly, self.rdwr].iter().filter(|&&b| b).count();
        if n != 1 {
            return Err(mpi_err!(Amode, "exactly one of RDONLY/WRONLY/RDWR required"));
        }
        if self.rdonly && (self.create || self.excl || self.append) {
            return Err(mpi_err!(Amode, "RDONLY is incompatible with CREATE/EXCL/APPEND"));
        }
        Ok(())
    }

    pub fn can_read(&self) -> bool {
        self.rdonly || self.rdwr
    }

    pub fn can_write(&self) -> bool {
        self.wronly || self.rdwr
    }
}

/// Issue one metadata op toward the file server and block for the reply
/// scalar (the engine keeps processing inbound packets while waiting, so
/// a blocked client still serves others in in-process mode).
fn run_meta(ctx: &Rc<RankCtx>, path: &str, op: u8, arg: u64) -> Result<u64> {
    let token = start_io(ctx, server_rank(ctx), IoKind::Meta { path: path.to_string(), op, arg });
    wait_for(ctx, || io_done(ctx, token))?;
    let (_, value) = take_io_result(ctx, token)?;
    Ok(value)
}

/// Which half of a split collective is outstanding.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum SplitKind {
    Read,
    Write,
}

struct PendingSplit {
    kind: SplitKind,
    req: Request,
    /// Bytes for the `_end` return value when known at begin time
    /// (writes); reads report the possibly-short completion status.
    bytes: Option<usize>,
}

/// `MPI_File`.
pub struct File {
    comm: Comm,
    path: String,
    amode: AccessMode,
    view: RefCell<View>,
    /// Individual file pointer, in *etypes*.
    ptr: Cell<u64>,
    atomicity: Cell<bool>,
    /// Per-handle two-phase override; `None` defers to the env knob.
    twophase: Cell<Option<bool>>,
    /// Tag-space sequencer for collective-IO ops on the private comm.
    op_seq: Cell<i32>,
    /// The outstanding split collective, if any (§14.4.5 allows one).
    split: RefCell<Option<PendingSplit>>,
}

impl std::fmt::Debug for File {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("File")
            .field("path", &self.path)
            .field("amode", &self.amode)
            .field("ptr", &self.ptr.get())
            .finish_non_exhaustive()
    }
}

impl File {
    /// `MPI_File_open` — collective over `comm`. Rank 0 runs the server
    /// transaction (opening one handle per rank at once) and the outcome
    /// is broadcast so every rank agrees.
    pub fn open(comm: &Comm, path: &str, amode: AccessMode) -> Result<File> {
        amode.validate()?;
        if comm.rank_ctx().fabric.is_multiprocess() && !server::server_enabled() {
            return Err(mpi_err!(
                Io,
                "MPI-IO on multi-process backends routes through the rank-0 file \
                 server, which is disabled (FERROMPI_IO_SERVER=0)"
            ));
        }
        let comm = comm.dup()?;
        let ctx = comm.rank_ctx().clone();
        let mut code = [0u8; 4];
        if comm.rank() == 0 {
            let flags = if amode.create { FLAG_CREATE } else { 0 }
                | if amode.excl { FLAG_EXCL } else { 0 };
            let arg = ((comm.size() as u64) << 8) | flags;
            let c = match run_meta(&ctx, path, OP_OPEN, arg) {
                Ok(_) => 0,
                Err(e) => e.class.code(),
            };
            code.copy_from_slice(&c.to_le_bytes());
        }
        let i32t = Datatype::primitive(Primitive::I32);
        collective::bcast(&comm, &mut code, 1, &i32t, 0)?;
        let code = i32::from_le_bytes(code);
        if code != 0 {
            return Err(MpiError::new(ErrorClass::from_code(code), format!("open '{path}'")));
        }
        let f = File {
            comm,
            path: path.to_string(),
            amode,
            view: RefCell::new(View::default()),
            ptr: Cell::new(0),
            atomicity: Cell::new(false),
            twophase: Cell::new(None),
            op_seq: Cell::new(0),
            split: RefCell::new(None),
        };
        if amode.append {
            f.ptr.set(f.size()? as u64);
        }
        Ok(f)
    }

    /// `MPI_File_delete` (non-collective, any rank).
    pub fn delete(comm: &Comm, path: &str) -> Result<()> {
        run_meta(comm.rank_ctx(), path, OP_DELETE, 0).map(|_| ())
    }

    /// `MPI_File_close` — collective; honors delete-on-close. The leading
    /// barrier guarantees every rank's operations completed before rank 0
    /// drops the handles.
    pub fn close(self) -> Result<()> {
        if self.split.borrow().is_some() {
            return Err(mpi_err!(Io, "close with an outstanding split collective"));
        }
        collective::barrier(&self.comm)?;
        let mut code = [0u8; 4];
        if self.comm.rank() == 0 {
            let flags = if self.amode.delete_on_close { FLAG_DELETE_ON_CLOSE } else { 0 };
            let arg = ((self.comm.size() as u64) << 8) | flags;
            let c = match run_meta(self.comm.rank_ctx(), &self.path, OP_CLOSE, arg) {
                Ok(_) => 0,
                Err(e) => e.class.code(),
            };
            code.copy_from_slice(&c.to_le_bytes());
        }
        let i32t = Datatype::primitive(Primitive::I32);
        collective::bcast(&self.comm, &mut code, 1, &i32t, 0)?;
        let code = i32::from_le_bytes(code);
        if code != 0 {
            return Err(MpiError::new(ErrorClass::from_code(code), format!("close '{}'", self.path)));
        }
        Ok(())
    }

    pub fn amode(&self) -> AccessMode {
        self.amode
    }

    pub fn comm(&self) -> &Comm {
        &self.comm
    }

    pub fn path(&self) -> &str {
        &self.path
    }

    /// Per-handle two-phase override: `Some(true)`/`Some(false)` force
    /// the collective-buffering path on or off, `None` defers to
    /// `FERROMPI_IO_TWOPHASE`. Set it collectively — all ranks of the
    /// file's communicator must agree or collective writes mismatch.
    pub fn set_twophase(&self, on: Option<bool>) {
        self.twophase.set(on);
    }

    fn use_twophase(&self) -> bool {
        self.twophase.get().unwrap_or_else(twophase_default)
    }

    /// Fresh tag base for one collective-IO op on the private comm.
    fn next_tags(&self) -> i32 {
        let s = self.op_seq.get();
        self.op_seq.set(s + 4);
        s
    }

    /// `MPI_File_get_size` (physical bytes).
    pub fn size(&self) -> Result<usize> {
        run_meta(self.comm.rank_ctx(), &self.path, OP_SIZE, 0).map(|v| v as usize)
    }

    /// `MPI_File_set_size` (truncate or zero-extend); collective. The
    /// leading barrier keeps the resize from racing operations other
    /// ranks issue before entering the call.
    pub fn set_size(&self, size: usize) -> Result<()> {
        collective::barrier(&self.comm)?;
        if self.comm.rank() == 0 {
            run_meta(self.comm.rank_ctx(), &self.path, OP_SET_SIZE, size as u64)?;
        }
        collective::barrier(&self.comm)
    }

    /// `MPI_File_preallocate`.
    pub fn preallocate(&self, size: usize) -> Result<()> {
        collective::barrier(&self.comm)?;
        if self.comm.rank() == 0 {
            run_meta(self.comm.rank_ctx(), &self.path, OP_PREALLOC, size as u64)?;
        }
        collective::barrier(&self.comm)
    }

    /// `MPI_File_set_view` — collective; resets both file pointers.
    pub fn set_view(&self, displacement: u64, etype: &Datatype, filetype: &Datatype) -> Result<()> {
        let v = View::new(displacement, etype.clone(), filetype.clone())?;
        *self.view.borrow_mut() = v;
        self.ptr.set(0);
        if self.comm.rank() == 0 {
            run_meta(self.comm.rank_ctx(), &self.path, OP_SHARED_SET, 0)?;
        }
        collective::barrier(&self.comm)
    }

    /// `MPI_File_get_view`.
    pub fn view(&self) -> View {
        self.view.borrow().clone()
    }

    /// `MPI_File_set_atomicity` / `get_atomicity`.
    pub fn set_atomicity(&self, on: bool) {
        self.atomicity.set(on);
    }

    pub fn atomicity(&self) -> bool {
        self.atomicity.get()
    }

    /// `MPI_File_sync`: a collective ordering point. The server applies
    /// operations in arrival order and every blocking/waited op implies
    /// its server-side completion, so the barrier is the only missing
    /// piece of the §14.6 semantics.
    pub fn sync(&self) -> Result<()> {
        collective::barrier(&self.comm)
    }

    // ---- explicit-offset ops (§14.4.2) ----

    /// Post the wire read for `count` elements at etype-offset `offset`
    /// and return the completion token (no waiting).
    fn start_read(&self, offset: u64, count: usize, dtype: &Datatype) -> Result<u64> {
        if !self.amode.can_read() {
            return Err(mpi_err!(Amode, "file not opened for reading"));
        }
        dtype.require_committed()?;
        let view = self.view.borrow();
        let ctx = self.comm.rank_ctx();
        Ok(start_io(
            ctx,
            server_rank(ctx),
            IoKind::Read {
                path: self.path.clone(),
                disp: view.displacement,
                map: view.filetype.shared_map(),
                lo: offset * view.etype.size() as u64,
                nbytes: dtype.size() * count,
            },
        ))
    }

    /// Pack and post the wire write; returns the completion token.
    fn start_write(&self, offset: u64, buf: &[u8], count: usize, dtype: &Datatype) -> Result<u64> {
        if !self.amode.can_write() {
            return Err(mpi_err!(Amode, "file not opened for writing"));
        }
        dtype.require_committed()?;
        let view = self.view.borrow();
        let ctx = self.comm.rank_ctx();
        let nbytes = dtype.size() * count;
        // Contiguous user bytes → wire buffer is the DMA-modeled single
        // memcpy (uncharged, like the send path); non-contiguous layouts
        // charge their pack.
        let mut wire = ctx.fabric.pool.take(nbytes);
        pack(dtype.map(), buf, count, &mut wire)?;
        if !dtype.map().is_contiguous() {
            ctx.fabric.pool.count_copied(nbytes);
        }
        Ok(start_io(
            ctx,
            server_rank(ctx),
            IoKind::Write {
                path: self.path.clone(),
                disp: view.displacement,
                map: view.filetype.shared_map(),
                lo: offset * view.etype.size() as u64,
                data: wire.freeze(),
            },
        ))
    }

    /// Unpack a completed read into `buf`; returns whole elements read
    /// (short at EOF).
    fn finish_read(data: &WireBytes, buf: &mut [u8], dtype: &Datatype) -> Result<usize> {
        let sz = dtype.size().max(1);
        let whole = data.len() / sz;
        unpack(dtype.map(), &data.as_slice()[..whole * dtype.size()], buf, whole)?;
        Ok(whole)
    }

    /// `MPI_File_read_at`: `offset` is in etypes. Returns elements read.
    pub fn read_at(&self, offset: u64, buf: &mut [u8], count: usize, dtype: &Datatype) -> Result<usize> {
        let ctx = self.comm.rank_ctx();
        let token = self.start_read(offset, count, dtype)?;
        wait_for(ctx, || io_done(ctx, token))?;
        let (data, _) = take_io_result(ctx, token)?;
        Self::finish_read(&data, buf, dtype)
    }

    /// `MPI_File_write_at`. Returns elements written.
    pub fn write_at(&self, offset: u64, buf: &[u8], count: usize, dtype: &Datatype) -> Result<usize> {
        let ctx = self.comm.rank_ctx();
        let token = self.start_write(offset, buf, count, dtype)?;
        wait_for(ctx, || io_done(ctx, token))?;
        take_io_result(ctx, token)?;
        Ok(count)
    }

    /// Build the request behind every collective-write entry point:
    /// two-phase aggregation when enabled and the communicator is
    /// non-trivial, otherwise an independent write followed by a
    /// nonblocking barrier (the collective contract without exchange).
    fn write_at_all_start(&self, offset: u64, buf: &[u8], count: usize, dtype: &Datatype) -> Result<Request> {
        if !self.amode.can_write() {
            return Err(mpi_err!(Amode, "file not opened for writing"));
        }
        if self.use_twophase() && self.comm.size() > 1 {
            let view = self.view.borrow().clone();
            let op = CollectiveWrite::begin(
                &self.comm,
                &self.path,
                &view,
                offset,
                buf,
                count,
                dtype,
                self.next_tags(),
            )?;
            Ok(Request::custom(self.comm.rank_ctx().clone(), op))
        } else {
            self.write_at(offset, buf, count, dtype)?;
            collective::ibarrier(&self.comm)
        }
    }

    /// Build the request behind the collective-read entry points: the
    /// independent wire read plus a nonblocking barrier, completing only
    /// when both have.
    fn read_at_all_start(&self, offset: u64, buf: &mut [u8], count: usize, dtype: &Datatype) -> Result<Request> {
        let token = self.start_read(offset, count, dtype)?;
        let barrier = collective::ibarrier(&self.comm)?;
        let ctx = self.comm.rank_ctx().clone();
        let op = Rc::new(IoOp {
            ctx: ctx.clone(),
            token,
            dest: RefCell::new(Some((RawBufMut::from_slice(buf), dtype.clone()))),
            barrier: Some(barrier),
        });
        Ok(Request::custom(ctx, op))
    }

    /// `MPI_File_read_at_all` / `write_at_all` — collective.
    pub fn read_at_all(&self, offset: u64, buf: &mut [u8], count: usize, dtype: &Datatype) -> Result<usize> {
        let n = self.read_at(offset, buf, count, dtype)?;
        collective::barrier(&self.comm)?;
        Ok(n)
    }

    pub fn write_at_all(&self, offset: u64, buf: &[u8], count: usize, dtype: &Datatype) -> Result<usize> {
        self.write_at_all_start(offset, buf, count, dtype)?.wait()?;
        Ok(count)
    }

    // ---- split collectives (§14.4.5) ----

    /// `MPI_File_write_at_all_begin`. One split collective may be
    /// outstanding per file handle; `begin` initiates (for two-phase,
    /// including the exchange planning collectives) and returns.
    pub fn write_at_all_begin(&self, offset: u64, buf: &[u8], count: usize, dtype: &Datatype) -> Result<()> {
        if self.split.borrow().is_some() {
            return Err(mpi_err!(Io, "a split collective is already outstanding on this file"));
        }
        let req = self.write_at_all_start(offset, buf, count, dtype)?;
        *self.split.borrow_mut() =
            Some(PendingSplit { kind: SplitKind::Write, req, bytes: Some(dtype.size() * count) });
        Ok(())
    }

    /// `MPI_File_write_at_all_end`: completes the outstanding split
    /// write; returns bytes written.
    pub fn write_at_all_end(&self) -> Result<usize> {
        let ps = self
            .split
            .borrow_mut()
            .take()
            .ok_or_else(|| mpi_err!(Io, "write_at_all_end without a matching begin"))?;
        if ps.kind != SplitKind::Write {
            *self.split.borrow_mut() = Some(ps);
            return Err(mpi_err!(Io, "write_at_all_end while a split read is outstanding"));
        }
        let st = ps.req.wait()?;
        Ok(ps.bytes.unwrap_or(st.bytes))
    }

    /// `MPI_File_read_at_all_begin`. The caller must keep `buf` alive
    /// and untouched until `read_at_all_end` (the standard's split-
    /// collective buffer contract).
    pub fn read_at_all_begin(&self, offset: u64, buf: &mut [u8], count: usize, dtype: &Datatype) -> Result<()> {
        if self.split.borrow().is_some() {
            return Err(mpi_err!(Io, "a split collective is already outstanding on this file"));
        }
        let req = self.read_at_all_start(offset, buf, count, dtype)?;
        *self.split.borrow_mut() = Some(PendingSplit { kind: SplitKind::Read, req, bytes: None });
        Ok(())
    }

    /// `MPI_File_read_at_all_end`: completes the outstanding split read;
    /// returns bytes read (short at EOF).
    pub fn read_at_all_end(&self) -> Result<usize> {
        let ps = self
            .split
            .borrow_mut()
            .take()
            .ok_or_else(|| mpi_err!(Io, "read_at_all_end without a matching begin"))?;
        if ps.kind != SplitKind::Read {
            *self.split.borrow_mut() = Some(ps);
            return Err(mpi_err!(Io, "read_at_all_end while a split write is outstanding"));
        }
        let st = ps.req.wait()?;
        Ok(st.bytes)
    }

    // ---- individual file pointer (§14.4.3) ----

    /// `MPI_File_seek` (whence = set).
    pub fn seek(&self, offset_etypes: u64) {
        self.ptr.set(offset_etypes);
    }

    /// `MPI_File_get_position` (etypes).
    pub fn position(&self) -> u64 {
        self.ptr.get()
    }

    fn advance_ptr(&self, elems: usize, dtype: &Datatype) {
        let esz = self.view.borrow().etype.size().max(1);
        self.ptr.set(self.ptr.get() + (elems * dtype.size() / esz) as u64);
    }

    /// `MPI_File_read`.
    pub fn read(&self, buf: &mut [u8], count: usize, dtype: &Datatype) -> Result<usize> {
        let n = self.read_at(self.ptr.get(), buf, count, dtype)?;
        self.advance_ptr(n, dtype);
        Ok(n)
    }

    /// `MPI_File_write`.
    pub fn write(&self, buf: &[u8], count: usize, dtype: &Datatype) -> Result<usize> {
        let n = self.write_at(self.ptr.get(), buf, count, dtype)?;
        self.advance_ptr(n, dtype);
        Ok(n)
    }

    /// `MPI_File_read_all` / `write_all`.
    pub fn read_all(&self, buf: &mut [u8], count: usize, dtype: &Datatype) -> Result<usize> {
        let n = self.read(buf, count, dtype)?;
        collective::barrier(&self.comm)?;
        Ok(n)
    }

    pub fn write_all(&self, buf: &[u8], count: usize, dtype: &Datatype) -> Result<usize> {
        let at = self.ptr.get();
        self.write_at_all_start(at, buf, count, dtype)?.wait()?;
        self.advance_ptr(count, dtype);
        Ok(count)
    }

    // ---- shared file pointer (§14.4.4) ----

    /// Fetch-and-add the server-held shared pointer; returns the old
    /// position (etypes).
    fn bump_shared(&self, etypes: u64) -> Result<u64> {
        run_meta(self.comm.rank_ctx(), &self.path, OP_SHARED_BUMP, etypes)
    }

    fn shared_etypes(&self, count: usize, dtype: &Datatype) -> u64 {
        let esz = self.view.borrow().etype.size().max(1);
        (dtype.size() * count / esz) as u64
    }

    /// `MPI_File_read_shared`.
    pub fn read_shared(&self, buf: &mut [u8], count: usize, dtype: &Datatype) -> Result<usize> {
        let at = self.bump_shared(self.shared_etypes(count, dtype))?;
        self.read_at(at, buf, count, dtype)
    }

    /// `MPI_File_write_shared`.
    pub fn write_shared(&self, buf: &[u8], count: usize, dtype: &Datatype) -> Result<usize> {
        let at = self.bump_shared(self.shared_etypes(count, dtype))?;
        self.write_at(at, buf, count, dtype)
    }

    /// `MPI_File_write_ordered`: rank-order offsets via an exscan of
    /// contribution sizes on top of the server-held shared pointer.
    pub fn write_ordered(&self, buf: &[u8], count: usize, dtype: &Datatype) -> Result<usize> {
        let mine = self.shared_etypes(count, dtype);
        let mut base = [0u8; 8];
        if self.comm.rank() == 0 {
            let b = run_meta(self.comm.rank_ctx(), &self.path, OP_SHARED_GET, 0)?;
            base.copy_from_slice(&b.to_le_bytes());
        }
        let u64t = Datatype::primitive(Primitive::U64);
        collective::bcast(&self.comm, &mut base, 1, &u64t, 0)?;
        let base = u64::from_le_bytes(base);
        let mut before = [0u8; 8];
        collective::exscan(&self.comm, Some(&mine.to_le_bytes()), &mut before, 1, &u64t, &Op::SUM)?;
        let before = if self.comm.rank() == 0 { 0 } else { u64::from_le_bytes(before) };
        let n = self.write_at(base + before, buf, count, dtype)?;
        let mut total = [0u8; 8];
        collective::allreduce(&self.comm, Some(&mine.to_le_bytes()), &mut total, 1, &u64t, &Op::SUM)?;
        if self.comm.rank() == 0 {
            let end = base + u64::from_le_bytes(total);
            run_meta(self.comm.rank_ctx(), &self.path, OP_SHARED_SET, end)?;
        }
        collective::barrier(&self.comm)?;
        Ok(n)
    }

    // ---- nonblocking (§14.4.5): first-class requests on the wire
    // path, completed by the progress engine. ----

    /// `MPI_File_iread_at`. The caller must keep `buf` alive and
    /// untouched until the request completes.
    pub fn iread_at(&self, offset: u64, buf: &mut [u8], count: usize, dtype: &Datatype) -> Result<Request> {
        let token = self.start_read(offset, count, dtype)?;
        let ctx = self.comm.rank_ctx().clone();
        let op = Rc::new(IoOp {
            ctx: ctx.clone(),
            token,
            dest: RefCell::new(Some((RawBufMut::from_slice(buf), dtype.clone()))),
            barrier: None,
        });
        Ok(Request::custom(ctx, op))
    }

    /// `MPI_File_iwrite_at`. The payload is packed at post time, so the
    /// buffer is free as soon as this returns.
    pub fn iwrite_at(&self, offset: u64, buf: &[u8], count: usize, dtype: &Datatype) -> Result<Request> {
        let token = self.start_write(offset, buf, count, dtype)?;
        let ctx = self.comm.rank_ctx().clone();
        let op = Rc::new(IoOp { ctx: ctx.clone(), token, dest: RefCell::new(None), barrier: None });
        Ok(Request::custom(ctx, op))
    }

    /// `MPI_File_iread` / `MPI_File_iwrite`: individual-pointer
    /// nonblocking ops. The pointer advances at post time by the
    /// requested amount (completion may still read short at EOF).
    pub fn iread(&self, buf: &mut [u8], count: usize, dtype: &Datatype) -> Result<Request> {
        let at = self.ptr.get();
        let r = self.iread_at(at, buf, count, dtype)?;
        self.advance_ptr(count, dtype);
        Ok(r)
    }

    pub fn iwrite(&self, buf: &[u8], count: usize, dtype: &Datatype) -> Result<Request> {
        let at = self.ptr.get();
        let r = self.iwrite_at(at, buf, count, dtype)?;
        self.advance_ptr(count, dtype);
        Ok(r)
    }

    /// `MPI_File_iread_at_all` / `iwrite_at_all`: nonblocking collective
    /// access. Initiation runs the (blocking) exchange-planning
    /// collectives; the data movement completes in the background —
    /// overlap computation between post and wait.
    pub fn iread_at_all(&self, offset: u64, buf: &mut [u8], count: usize, dtype: &Datatype) -> Result<Request> {
        self.read_at_all_start(offset, buf, count, dtype)
    }

    pub fn iwrite_at_all(&self, offset: u64, buf: &[u8], count: usize, dtype: &Datatype) -> Result<Request> {
        self.write_at_all_start(offset, buf, count, dtype)
    }

    /// `MPI_File_iread_shared` / `iwrite_shared`: the shared-pointer
    /// fetch-and-add and the data op chain through the progress engine
    /// without blocking.
    pub fn iread_shared(&self, buf: &mut [u8], count: usize, dtype: &Datatype) -> Result<Request> {
        self.start_shared(None, Some((RawBufMut::from_slice(buf), dtype.clone())), count, dtype)
    }

    pub fn iwrite_shared(&self, buf: &[u8], count: usize, dtype: &Datatype) -> Result<Request> {
        if !self.amode.can_write() {
            return Err(mpi_err!(Amode, "file not opened for writing"));
        }
        dtype.require_committed()?;
        let ctx = self.comm.rank_ctx();
        let nbytes = dtype.size() * count;
        let mut wire = ctx.fabric.pool.take(nbytes);
        pack(dtype.map(), buf, count, &mut wire)?;
        if !dtype.map().is_contiguous() {
            ctx.fabric.pool.count_copied(nbytes);
        }
        self.start_shared(Some(wire.freeze()), None, count, dtype)
    }

    fn start_shared(
        &self,
        payload: Option<WireBytes>,
        dest: Option<(RawBufMut, Datatype)>,
        count: usize,
        dtype: &Datatype,
    ) -> Result<Request> {
        if dest.is_some() {
            if !self.amode.can_read() {
                return Err(mpi_err!(Amode, "file not opened for reading"));
            }
            dtype.require_committed()?;
        }
        let ctx = self.comm.rank_ctx().clone();
        let view = self.view.borrow();
        let bump = start_io(
            &ctx,
            server_rank(&ctx),
            IoKind::Meta {
                path: self.path.clone(),
                op: OP_SHARED_BUMP,
                arg: self.shared_etypes(count, dtype),
            },
        );
        let op = Rc::new(SharedIoOp {
            ctx: ctx.clone(),
            path: self.path.clone(),
            disp: view.displacement,
            map: view.filetype.shared_map(),
            esz: view.etype.size().max(1) as u64,
            nbytes: dtype.size() * count,
            bump: Cell::new(Some(bump)),
            data: Cell::new(None),
            payload: RefCell::new(payload),
            dest: RefCell::new(dest),
            error: RefCell::new(None),
            done: Cell::new(false),
        });
        ctx.register_progressable(op.clone());
        Ok(Request::custom(ctx, op))
    }
}

/// A single wire IO op as a request: read (with unpack destination) or
/// write, optionally fused with a nonblocking barrier (the collective
/// read path).
struct IoOp {
    ctx: Rc<RankCtx>,
    token: u64,
    /// Read destination: raw capture of the user buffer plus its type.
    dest: RefCell<Option<(RawBufMut, Datatype)>>,
    /// The collective contract, when this op backs `*_at_all`.
    barrier: Option<Request>,
}

impl CustomRequest for IoOp {
    fn done(&self) -> bool {
        io_done(&self.ctx, self.token)
            && self.barrier.as_ref().map_or(true, |b| b.test_ready_nonconsuming())
    }

    fn take_status(&self) -> Result<Status> {
        if let Some(b) = &self.barrier {
            // Already complete (done() gated on it); consumes without
            // blocking.
            b.wait()?;
        }
        let (data, value) = take_io_result(&self.ctx, self.token)?;
        match self.dest.borrow_mut().take() {
            Some((buf, dtype)) => {
                let sz = dtype.size().max(1);
                let whole = data.len() / sz;
                let out = unsafe { buf.as_slice_mut() };
                unpack(dtype.map(), &data.as_slice()[..whole * dtype.size()], out, whole)?;
                Ok(Status { source: 0, tag: 0, bytes: whole * dtype.size(), cancelled: false })
            }
            None => Ok(Status { source: 0, tag: 0, bytes: value as usize, cancelled: false }),
        }
    }
}

/// A shared-pointer nonblocking op: stage 1 is the server-side
/// fetch-and-add, stage 2 the data transfer at the returned offset. The
/// chaining happens in `advance` (packet injection only — no engine
/// re-entry), so the whole chain is progress-driven.
struct SharedIoOp {
    ctx: Rc<RankCtx>,
    path: String,
    disp: u64,
    map: Arc<TypeMap>,
    esz: u64,
    nbytes: usize,
    bump: Cell<Option<u64>>,
    data: Cell<Option<u64>>,
    /// Pre-packed write payload (None for reads).
    payload: RefCell<Option<WireBytes>>,
    /// Read destination (None for writes).
    dest: RefCell<Option<(RawBufMut, Datatype)>>,
    error: RefCell<Option<MpiError>>,
    done: Cell<bool>,
}

impl crate::p2p::Progressable for SharedIoOp {
    fn advance(&self, ctx: &Rc<RankCtx>) -> Result<bool> {
        if let Some(b) = self.bump.get() {
            if !io_done(ctx, b) {
                return Ok(false);
            }
            self.bump.set(None);
            match take_io_result(ctx, b) {
                Err(e) => {
                    *self.error.borrow_mut() = Some(e);
                    self.done.set(true);
                    return Ok(true);
                }
                Ok((_, old)) => {
                    let lo = old * self.esz;
                    let kind = match self.payload.borrow_mut().take() {
                        Some(data) => IoKind::Write {
                            path: self.path.clone(),
                            disp: self.disp,
                            map: self.map.clone(),
                            lo,
                            data,
                        },
                        None => IoKind::Read {
                            path: self.path.clone(),
                            disp: self.disp,
                            map: self.map.clone(),
                            lo,
                            nbytes: self.nbytes,
                        },
                    };
                    self.data.set(Some(start_io(ctx, server_rank(ctx), kind)));
                }
            }
        }
        let finished = self.data.get().is_some_and(|t| io_done(ctx, t));
        if finished {
            self.done.set(true);
        }
        Ok(finished)
    }
}

impl CustomRequest for SharedIoOp {
    fn done(&self) -> bool {
        self.done.get()
    }

    fn take_status(&self) -> Result<Status> {
        if let Some(e) = self.error.borrow_mut().take() {
            return Err(e);
        }
        let token = self.data.get().ok_or_else(|| mpi_err!(Intern, "shared io op has no data token"))?;
        let (data, value) = take_io_result(&self.ctx, token)?;
        match self.dest.borrow_mut().take() {
            Some((buf, dtype)) => {
                let sz = dtype.size().max(1);
                let whole = data.len() / sz;
                let out = unsafe { buf.as_slice_mut() };
                unpack(dtype.map(), &data.as_slice()[..whole * dtype.size()], out, whole)?;
                Ok(Status { source: 0, tag: 0, bytes: whole * dtype.size(), cancelled: false })
            }
            None => Ok(Status { source: 0, tag: 0, bytes: value as usize, cancelled: false }),
        }
    }
}
