//! Two-phase collective write buffering (§14.4.5's "collective buffering"
//! optimization, the heart of every production ROMIO-style MPI-IO stack).
//!
//! A collective write over strided per-rank views produces, naively, a
//! storm of small non-contiguous file ops. Two-phase IO rearranges the
//! same bytes in two steps:
//!
//! 1. **Exchange** — the file span under write is cut into fixed-width
//!    *stripes*, each owned by one *aggregator* rank (selection keyed on
//!    the communicator topology via
//!    [`decide_io_aggregators`](crate::collective::tuned::decide_io_aggregators):
//!    roughly one per node). Every rank splits its physical runs at
//!    stripe boundaries and ships each fragment to the owning aggregator
//!    as one framed message (`[n_runs][(off,len)…][payload]`) over the
//!    ordinary p2p path — pooled wire buffers, credits, chaos and the
//!    cost model all apply. A rank's fragments for a stripe it owns
//!    itself stay local.
//! 2. **Write** — each aggregator merges the fragments it collected
//!    (sorted by `(offset, source rank)`, so overlaps resolve
//!    deterministically with the higher rank winning), coalesces adjacent
//!    runs into contiguous segments, stages each segment through a pooled
//!    exchange buffer, and injects one `IoWrite` per segment toward the
//!    file server ([`server_rank`](super::server::server_rank)). When its
//!    segments are acknowledged it broadcasts a zero-byte *done-note*;
//!    the collective completes on a rank only once every aggregator's
//!    note has arrived, so no rank can observe a torn write after its own
//!    `write_at_all` returns.
//!
//! Copy accounting: payload bytes staged through the exchange — the
//! scatter into per-aggregator messages at the source and the gather into
//! contiguous segments at the aggregator — are *genuine* CPU copies and
//! are charged to both `wire_bytes_copied` and the `io_aggregated_bytes`
//! pvar. Nothing else on the collective-IO path charges, so with
//! contiguous user buffers the two counters stay equal (and both stay
//! zero with two-phase disabled) — pinned by `tests/test_io.rs`.
//!
//! The op is a [`Progressable`] driven by the ordinary engine loop and a
//! [`CustomRequest`], so the same object backs blocking `write_at_all`,
//! split `write_at_all_begin/_end`, and nonblocking `iwrite_at_all`.
//! `begin` runs on the user thread and may block in collectives (span
//! reduction, per-aggregator size allgather); `advance` never blocks and
//! never re-enters the engine — it only polls completion tokens.

use super::server::server_rank;
use super::view::View;
use crate::collective;
use crate::collective::tuned::{comm_topo, decide_io_aggregators};
use crate::comm::Comm;
use crate::datatype::{pack, Datatype, Primitive, TypeMap};
use crate::error::MpiError;
use crate::group::Group;
use crate::op::Op;
use crate::p2p::engine::start_send;
use crate::p2p::{
    engine, post_recv, IoKind, Progressable, RankCtx, RawBufMut, RndvStaging, SendMode, SendParams,
    Status,
};
use crate::request::CustomRequest;
use crate::Result;
use std::cell::{Cell, RefCell};
use std::rc::Rc;
use std::sync::atomic::Ordering;
use std::sync::Arc;

/// `FERROMPI_IO_STRIPE`: exchange stripe width in bytes (default 64 KiB).
pub fn stripe_bytes() -> usize {
    std::env::var("FERROMPI_IO_STRIPE")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&s| s > 0)
        .unwrap_or(64 * 1024)
}

/// `FERROMPI_IO_TWOPHASE`: whether collective writes aggregate (default
/// on). [`File::set_twophase`](super::File::set_twophase) overrides it
/// per handle.
pub fn twophase_default() -> bool {
    std::env::var("FERROMPI_IO_TWOPHASE").map_or(true, |v| v != "0")
}

// ---------------- pure exchange planning ----------------

/// One stripe-bounded piece of a rank's write, in logical payload order.
/// `pos` is the byte position of this fragment's data in the rank's
/// packed payload.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct Frag {
    off: u64,
    len: usize,
    pos: usize,
}

/// Split physical runs at stripe boundaries and bin them by owning
/// aggregator (`stripe_index % n_aggregators`). Runs arrive in logical
/// payload order; each bin preserves that order, so a bin's payload is
/// the in-order concatenation of its fragments' bytes.
fn bin_runs(runs: &[(u64, usize)], stripe: usize, naggs: usize) -> Vec<Vec<Frag>> {
    let mut bins = vec![Vec::new(); naggs];
    let mut pos = 0usize;
    for &(mut off, mut len) in runs {
        while len > 0 {
            let s = off / stripe as u64;
            let take = len.min(((s + 1) * stripe as u64 - off) as usize);
            bins[(s % naggs as u64) as usize].push(Frag { off, len: take, pos });
            off += take as u64;
            pos += take;
            len -= take;
        }
    }
    bins
}

/// Frame one aggregator-bound message:
/// `[u32 n_runs][(u64 off, u64 len) × n][payload bytes in run order]`.
fn encode_msg(frags: &[Frag], payload: &[u8]) -> Vec<u8> {
    let data: usize = frags.iter().map(|f| f.len).sum();
    let mut msg = Vec::with_capacity(4 + 16 * frags.len() + data);
    msg.extend_from_slice(&(frags.len() as u32).to_le_bytes());
    for f in frags {
        msg.extend_from_slice(&f.off.to_le_bytes());
        msg.extend_from_slice(&(f.len as u64).to_le_bytes());
    }
    for f in frags {
        msg.extend_from_slice(&payload[f.pos..f.pos + f.len]);
    }
    msg
}

/// Parse a framed exchange message back into `(runs, payload offset)`.
/// `None` on a malformed frame (truncated header, or a payload shorter
/// than the runs claim).
fn parse_msg(msg: &[u8]) -> Option<(Vec<(u64, usize)>, usize)> {
    let n = u32::from_le_bytes(msg.get(..4)?.try_into().ok()?) as usize;
    let body = 4 + 16 * n;
    let mut runs = Vec::with_capacity(n);
    let mut total = 0usize;
    for i in 0..n {
        let at = 4 + 16 * i;
        let off = u64::from_le_bytes(msg.get(at..at + 8)?.try_into().ok()?);
        let len = u64::from_le_bytes(msg.get(at + 8..at + 16)?.try_into().ok()?) as usize;
        runs.push((off, len));
        total += len;
    }
    if msg.len() < body + total {
        return None;
    }
    Some((runs, body))
}

/// A fragment an aggregator collected: where it lands in the file and
/// where its bytes live (`msg` indexes the collected-message list,
/// `pos` the payload position inside that message).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct Collected {
    off: u64,
    len: usize,
    src: usize,
    msg: usize,
    pos: usize,
}

/// One contiguous staged write: `[start, end)` covered by `frags` in
/// deterministic overwrite order.
struct Segment {
    start: u64,
    end: u64,
    frags: Vec<Collected>,
}

/// Merge collected fragments into contiguous segments. Sorting by
/// `(off, src)` makes overlap resolution deterministic (a later copy in
/// segment order overwrites an earlier one, so the highest contributing
/// rank wins byte-wise) — the chaos differential depends on this.
fn plan_segments(mut frags: Vec<Collected>) -> Vec<Segment> {
    frags.sort_by_key(|f| (f.off, f.src));
    let mut segs: Vec<Segment> = Vec::new();
    for f in frags {
        match segs.last_mut() {
            Some(s) if f.off <= s.end => {
                s.end = s.end.max(f.off + f.len as u64);
                s.frags.push(f);
            }
            _ => segs.push(Segment { start: f.off, end: f.off + f.len as u64, frags: vec![f] }),
        }
    }
    segs
}

// ---------------- the collective-write op ----------------

/// Aggregator-side state: inbound exchange messages and the staged
/// writes they turn into.
struct AggState {
    /// `(source group rank, recv token)` for each expected message.
    recv_tokens: RefCell<Vec<(usize, u64)>>,
    /// `(source group rank, message bytes)` — exact-size buffers the
    /// recvs above land in, plus this rank's own (local) message. The
    /// inner vectors are never resized after posting: the engine holds
    /// raw pointers into their heap storage.
    bufs: Vec<(usize, Vec<u8>)>,
    /// Exchange messages merged and `IoWrite`s injected.
    assembled: Cell<bool>,
    io_tokens: RefCell<Vec<u64>>,
}

/// A two-phase collective write in flight (see the module docs). Created
/// by [`CollectiveWrite::begin`] on the user thread; completed by the
/// progress engine. Backs blocking, split and nonblocking collective
/// writes alike via [`Request::custom`](crate::request::Request::custom).
pub struct CollectiveWrite {
    group: Group,
    ctx_id: u32,
    path: String,
    tag_note: i32,
    /// World ranks of every aggregator, in slot order.
    agg_worlds: Vec<usize>,
    /// Set when this rank owns an aggregator slot.
    agg: Option<AggState>,
    byte_map: Arc<TypeMap>,
    data_sends: RefCell<Vec<u64>>,
    note_sends: RefCell<Vec<u64>>,
    note_recvs: RefCell<Vec<u64>>,
    notes_sent: Cell<bool>,
    error: RefCell<Option<MpiError>>,
    done: Cell<bool>,
    /// User payload bytes this rank contributed (for the final status).
    bytes: usize,
}

impl CollectiveWrite {
    /// Run the exchange-planning collectives and post all communication.
    /// Collective over `comm`. `tag_base` must be distinct per
    /// outstanding op on the file's private communicator (the caller's
    /// `op_seq` provides it); this op uses `tag_base` for exchange data
    /// and `tag_base + 1` for done-notes. The returned op is already
    /// registered with the progress engine — wrap it in a
    /// [`Request`](crate::request::Request) to wait on it.
    #[allow(clippy::too_many_arguments)]
    pub fn begin(
        comm: &Comm,
        path: &str,
        view: &View,
        offset: u64,
        buf: &[u8],
        count: usize,
        dtype: &Datatype,
        tag_base: i32,
    ) -> Result<Rc<CollectiveWrite>> {
        dtype.require_committed()?;
        let ctx = comm.rank_ctx();
        let p = comm.size();
        let me = comm.rank();
        let nbytes = dtype.size() * count;
        let tag_data = tag_base;
        let tag_note = tag_base + 1;
        let byte = Datatype::primitive(Primitive::Byte);

        // Pack the user payload once. The pack engine's contiguous fast
        // path is an uncharged single memcpy (DMA-modeled, as on the send
        // path); non-contiguous layouts charge like any other pack.
        let mut payload = Vec::with_capacity(nbytes);
        pack(dtype.map(), buf, count, &mut payload)?;
        if !dtype.map().is_contiguous() {
            ctx.fabric.pool.count_copied(nbytes);
        }
        let lo = offset * view.etype.size() as u64;
        let runs = view.runs(lo, nbytes);

        // Agree on the file span under write (min/max over all ranks).
        let u64t = Datatype::primitive(Primitive::U64);
        let my_lo = runs.first().map_or(u64::MAX, |r| r.0);
        let my_hi = runs.iter().map(|r| r.0 + r.1 as u64).max().unwrap_or(0);
        let mut span_lo = [0u8; 8];
        let mut span_hi = [0u8; 8];
        collective::allreduce(comm, Some(&my_lo.to_le_bytes()), &mut span_lo, 1, &u64t, &Op::MIN)?;
        collective::allreduce(comm, Some(&my_hi.to_le_bytes()), &mut span_hi, 1, &u64t, &Op::MAX)?;
        let (span_lo, span_hi) = (u64::from_le_bytes(span_lo), u64::from_le_bytes(span_hi));

        if span_hi <= span_lo {
            // No rank wrote anything — the span reductions were the
            // synchronization; there is nothing to exchange.
            return Ok(Rc::new(CollectiveWrite {
                group: comm.group().clone(),
                ctx_id: comm.ctx_p2p(),
                path: path.to_string(),
                tag_note,
                agg_worlds: Vec::new(),
                agg: None,
                byte_map: Arc::new(TypeMap::primitive(Primitive::Byte)),
                data_sends: RefCell::new(Vec::new()),
                note_sends: RefCell::new(Vec::new()),
                note_recvs: RefCell::new(Vec::new()),
                notes_sent: Cell::new(true),
                error: RefCell::new(None),
                done: Cell::new(true),
                bytes: nbytes,
            }));
        }

        // Plan the exchange: aggregator count from the tuned table,
        // aggregator ranks spread evenly over the communicator (which
        // spreads them over nodes under block rank placement).
        let stripe = stripe_bytes();
        let naggs = decide_io_aggregators(comm_topo(comm), stripe, (span_hi - span_lo) as usize);
        let agg_ranks: Vec<usize> = (0..naggs).map(|k| k * p / naggs).collect();
        let my_slot = agg_ranks.iter().position(|&r| r == me);

        // Bin my runs by owning aggregator and frame the messages. The
        // payload scatter into the frames is the client half of the
        // exchange staging — charged (see the module docs).
        let bins = bin_runs(&runs, stripe, naggs);
        let mut msgs: Vec<Option<Vec<u8>>> = Vec::with_capacity(naggs);
        let mut sizes = vec![0u8; naggs * 8];
        for (k, frags) in bins.iter().enumerate() {
            if frags.is_empty() {
                msgs.push(None);
                continue;
            }
            let staged: usize = frags.iter().map(|f| f.len).sum();
            ctx.fabric.pool.count_copied(staged);
            ctx.fabric.stats.io_aggregated_bytes.fetch_add(staged as u64, Ordering::Relaxed);
            let m = encode_msg(frags, &payload);
            sizes[k * 8..k * 8 + 8].copy_from_slice(&(m.len() as u64).to_le_bytes());
            msgs.push(Some(m));
        }

        // Everyone learns every (source, aggregator) message size, so
        // aggregators can post exact-size receives up front.
        let mut all_sizes = vec![0u8; p * naggs * 8];
        collective::allgather(comm, Some(&sizes), naggs, &u64t, &mut all_sizes, naggs, &u64t)?;
        let size_of = |src: usize, k: usize| {
            let at = (src * naggs + k) * 8;
            u64::from_le_bytes(all_sizes[at..at + 8].try_into().unwrap()) as usize
        };

        let group = comm.group().clone();
        let ctx_id = comm.ctx_p2p();

        // Aggregator slot: post one exact-size receive per contributing
        // peer. The inner `Vec`s' heap storage is stable across the later
        // move into the op, which is what makes the raw-pointer capture
        // in `post_recv` sound.
        let agg = match my_slot {
            None => None,
            Some(slot) => {
                let mut bufs: Vec<(usize, Vec<u8>)> = Vec::new();
                let mut recv_tokens = Vec::new();
                for src in 0..p {
                    if src == me || size_of(src, slot) == 0 {
                        continue;
                    }
                    bufs.push((src, vec![0u8; size_of(src, slot)]));
                }
                for (src, b) in bufs.iter_mut() {
                    let n = b.len();
                    let token = post_recv(
                        ctx,
                        ctx_id,
                        Some(group.world_rank(*src)?),
                        Some(tag_data),
                        RawBufMut::from_slice(b),
                        n,
                        byte.clone(),
                        group.clone(),
                    )?;
                    recv_tokens.push((*src, token));
                }
                if let Some(own) = msgs[slot].take() {
                    bufs.push((me, own));
                }
                Some(AggState {
                    recv_tokens: RefCell::new(recv_tokens),
                    bufs,
                    assembled: Cell::new(false),
                    io_tokens: RefCell::new(Vec::new()),
                })
            }
        };

        // Every rank waits for a done-note from every aggregator it is
        // not itself — that barrier-with-meaning is what makes the
        // collective's return imply "bytes are on the server".
        let mut note_recvs = Vec::new();
        for &ar in &agg_ranks {
            if ar == me {
                continue;
            }
            let token = post_recv(
                ctx,
                ctx_id,
                Some(group.world_rank(ar)?),
                Some(tag_note),
                RawBufMut::from_slice(&mut []),
                0,
                byte.clone(),
                group.clone(),
            )?;
            note_recvs.push(token);
        }

        // Ship my fragments to their aggregators.
        let mut data_sends = Vec::new();
        for (k, m) in msgs.iter().enumerate() {
            let Some(m) = m else { continue };
            if let Some(token) = start_send(
                ctx,
                SendParams {
                    ctx_id,
                    dst_world: group.world_rank(agg_ranks[k])?,
                    tag: tag_data,
                    buf: m,
                    count: m.len(),
                    dtype: &byte,
                    mode: SendMode::Standard,
                    staging: RndvStaging::Staged,
                },
            )? {
                data_sends.push(token);
            }
        }

        let mut agg_worlds = Vec::with_capacity(naggs);
        for &ar in &agg_ranks {
            agg_worlds.push(group.world_rank(ar)?);
        }
        let op = Rc::new(CollectiveWrite {
            group,
            ctx_id,
            path: path.to_string(),
            tag_note,
            agg_worlds,
            agg,
            byte_map: Arc::new(TypeMap::primitive(Primitive::Byte)),
            data_sends: RefCell::new(data_sends),
            note_sends: RefCell::new(Vec::new()),
            note_recvs: RefCell::new(note_recvs),
            notes_sent: Cell::new(false),
            error: RefCell::new(None),
            done: Cell::new(false),
            bytes: nbytes,
        });
        ctx.register_progressable(op.clone());
        Ok(op)
    }

    fn record(&self, e: MpiError) {
        self.error.borrow_mut().get_or_insert(e);
    }

    /// Merge the collected exchange messages, stage each contiguous
    /// segment through a pooled buffer (the charged aggregator half of
    /// the exchange) and inject one `IoWrite` per segment.
    fn assemble_and_write(&self, ctx: &Rc<RankCtx>, agg: &AggState) {
        let mut frags = Vec::new();
        let mut payload_at = vec![0usize; agg.bufs.len()];
        for (i, (src, msg)) in agg.bufs.iter().enumerate() {
            match parse_msg(msg) {
                Some((runs, body)) => {
                    payload_at[i] = body;
                    let mut pos = body;
                    for (off, len) in runs {
                        frags.push(Collected { off, len, src: *src, msg: i, pos });
                        pos += len;
                    }
                }
                None => self.record(crate::mpi_err!(
                    Io,
                    "malformed two-phase exchange message from rank {src}"
                )),
            }
        }
        let server = server_rank(ctx);
        for seg in plan_segments(frags) {
            let len = (seg.end - seg.start) as usize;
            let mut staged = ctx.fabric.pool.take(len);
            staged.resize(len, 0);
            for f in &seg.frags {
                let at = (f.off - seg.start) as usize;
                staged[at..at + f.len].copy_from_slice(&agg.bufs[f.msg].1[f.pos..f.pos + f.len]);
            }
            ctx.fabric.pool.count_copied(len);
            ctx.fabric.stats.io_aggregated_bytes.fetch_add(len as u64, Ordering::Relaxed);
            let token = engine::start_io(
                ctx,
                server,
                IoKind::Write {
                    path: self.path.clone(),
                    disp: 0,
                    map: self.byte_map.clone(),
                    lo: seg.start,
                    data: staged.freeze(),
                },
            );
            agg.io_tokens.borrow_mut().push(token);
        }
    }
}

impl Progressable for CollectiveWrite {
    /// One non-blocking turn. Never returns `Err`: failures are recorded
    /// on the op (surfaced by `take_status`) while the machinery drains,
    /// so one rank's IO error cannot wedge its peers mid-exchange.
    fn advance(&self, ctx: &Rc<RankCtx>) -> Result<bool> {
        self.data_sends.borrow_mut().retain(|&t| !engine::take_send_done(ctx, t));
        self.note_sends.borrow_mut().retain(|&t| !engine::take_send_done(ctx, t));
        self.note_recvs.borrow_mut().retain(|&t| match engine::take_recv_result(ctx, t) {
            None => true,
            Some(Ok(_)) => false,
            Some(Err(e)) => {
                self.record(e);
                false
            }
        });

        if let Some(agg) = &self.agg {
            if !agg.assembled.get()
                && agg.recv_tokens.borrow().iter().all(|&(_, t)| engine::recv_done(ctx, t))
            {
                for (_, t) in agg.recv_tokens.borrow_mut().drain(..) {
                    if let Some(Err(e)) = engine::take_recv_result(ctx, t) {
                        self.record(e);
                    }
                }
                self.assemble_and_write(ctx, agg);
                agg.assembled.set(true);
            }
            if agg.assembled.get()
                && !self.notes_sent.get()
                && agg.io_tokens.borrow().iter().all(|&t| engine::io_done(ctx, t))
            {
                for t in agg.io_tokens.borrow_mut().drain(..) {
                    if let Err(e) = engine::take_io_result(ctx, t) {
                        self.record(e);
                    }
                }
                // The stripes are on the server — tell everyone.
                let byte = Datatype::primitive(Primitive::Byte);
                for &w in self.group.members() {
                    if w == ctx.world_rank {
                        continue;
                    }
                    match start_send(
                        ctx,
                        SendParams {
                            ctx_id: self.ctx_id,
                            dst_world: w,
                            tag: self.tag_note,
                            buf: &[],
                            count: 0,
                            dtype: &byte,
                            mode: SendMode::Standard,
                            staging: RndvStaging::Staged,
                        },
                    ) {
                        Ok(Some(t)) => self.note_sends.borrow_mut().push(t),
                        Ok(None) => {}
                        Err(e) => self.record(e),
                    }
                }
                self.notes_sent.set(true);
            }
        }

        let finished = self.agg.as_ref().map_or(true, |_| self.notes_sent.get())
            && self.data_sends.borrow().is_empty()
            && self.note_sends.borrow().is_empty()
            && self.note_recvs.borrow().is_empty();
        if finished {
            self.done.set(true);
        }
        Ok(finished)
    }
}

impl CustomRequest for CollectiveWrite {
    fn done(&self) -> bool {
        self.done.get()
    }

    fn take_status(&self) -> Result<Status> {
        match self.error.borrow_mut().take() {
            Some(e) => Err(e),
            None => Ok(Status { source: 0, tag: 0, bytes: self.bytes, cancelled: false }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn binning_splits_at_stripe_boundaries_in_payload_order() {
        // Two runs; stripe 8; 2 aggregators. Run 1 spans stripes 0|1,
        // run 2 sits in stripe 3 (owner 3 % 2 = 1).
        let bins = bin_runs(&[(4, 10), (26, 3)], 8, 2);
        assert_eq!(bins[0], vec![Frag { off: 4, len: 4, pos: 0 }]);
        assert_eq!(
            bins[1],
            vec![Frag { off: 8, len: 6, pos: 4 }, Frag { off: 26, len: 3, pos: 10 }]
        );
        // Payload positions tile the payload exactly.
        let total: usize = bins.iter().flatten().map(|f| f.len).sum();
        assert_eq!(total, 13);
    }

    #[test]
    fn message_frame_roundtrips() {
        let payload: Vec<u8> = (0..20u8).collect();
        let frags = [Frag { off: 100, len: 12, pos: 0 }, Frag { off: 300, len: 8, pos: 12 }];
        let msg = encode_msg(&frags, &payload);
        let (runs, body) = parse_msg(&msg).unwrap();
        assert_eq!(runs, vec![(100, 12), (300, 8)]);
        assert_eq!(&msg[body..], &payload[..]);
        // Truncation in the header or payload is rejected, not a panic.
        assert!(parse_msg(&msg[..3]).is_none());
        assert!(parse_msg(&msg[..msg.len() - 1]).is_none());
        // The degenerate empty frame roundtrips too.
        let empty = encode_msg(&[], &[]);
        assert_eq!(parse_msg(&empty), Some((vec![], 4)));
    }

    #[test]
    fn segment_planning_coalesces_and_orders_overlaps() {
        let f = |off, len, src| Collected { off, len, src, msg: 0, pos: 0 };
        // Adjacent + overlapping fragments from two ranks, out of order.
        let segs = plan_segments(vec![f(8, 4, 1), f(0, 8, 0), f(6, 4, 0), f(32, 8, 1)]);
        assert_eq!(segs.len(), 2);
        assert_eq!((segs[0].start, segs[0].end), (0, 12));
        assert_eq!((segs[1].start, segs[1].end), (32, 40));
        // Overwrite order inside a segment: (off, src) ascending, so the
        // rank-1 fragment at offset 8 lands after rank 0's at 6.
        let order: Vec<(u64, usize)> = segs[0].frags.iter().map(|f| (f.off, f.src)).collect();
        assert_eq!(order, vec![(0, 0), (6, 0), (8, 1)]);
    }

    #[test]
    fn knob_defaults() {
        assert_eq!(stripe_bytes(), 64 * 1024);
        assert!(twophase_default());
    }
}
