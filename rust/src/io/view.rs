//! File views (§14.3): displacement + elementary type + filetype.

use crate::datatype::{Datatype, Primitive};
use crate::{mpi_err, Result};

/// A rank's window onto a file. The filetype tiles the file starting at
/// `displacement`; only bytes covered by the filetype's typemap entries
/// are visible to this rank, in entry order.
#[derive(Debug, Clone)]
pub struct View {
    pub displacement: u64,
    pub etype: Datatype,
    pub filetype: Datatype,
}

impl Default for View {
    /// The default view: a byte stream from offset 0.
    fn default() -> View {
        let byte = Datatype::primitive(Primitive::Byte);
        View { displacement: 0, etype: byte.clone(), filetype: byte }
    }
}

impl View {
    pub fn new(displacement: u64, etype: Datatype, filetype: Datatype) -> Result<View> {
        if filetype.size() == 0 || filetype.size() % etype.size().max(1) != 0 {
            return Err(mpi_err!(
                UnsupportedDatarep,
                "filetype size {} not a multiple of etype size {}",
                filetype.size(),
                etype.size()
            ));
        }
        Ok(View { displacement, etype, filetype })
    }

    /// Bytes visible per filetype tile.
    pub fn tile_bytes(&self) -> usize {
        self.filetype.size()
    }

    /// Physical file extent of one tile.
    pub fn tile_extent(&self) -> usize {
        self.filetype.extent() as usize
    }

    /// Map a *logical* byte offset (within this rank's view) to the
    /// physical file offset.
    pub fn physical(&self, logical: u64) -> u64 {
        let tb = self.tile_bytes() as u64;
        let tile = logical / tb;
        let mut within = (logical % tb) as usize;
        for &(p, d) in self.filetype.map().entries() {
            let s = p.size();
            if within < s {
                return self.displacement
                    + tile * self.tile_extent() as u64
                    + (d as i64 + within as i64) as u64;
            }
            within -= s;
        }
        unreachable!("within < tile_bytes by construction")
    }

    /// Copy `len` logical bytes starting at logical offset `lo` from the
    /// file into `out`, mapping through the view. The file is grown on
    /// reads past EOF? No — reads past EOF yield the actual short count.
    pub fn read(&self, file: &[u8], lo: u64, out: &mut [u8]) -> usize {
        let mut done = 0;
        while done < out.len() {
            let phys = self.physical(lo + done as u64) as usize;
            if phys >= file.len() {
                break;
            }
            // Run length: contiguous both logically (within one entry) and
            // physically.
            let tb = self.tile_bytes() as u64;
            let within = ((lo + done as u64) % tb) as usize;
            let run = self.entry_run(within).min(out.len() - done).min(file.len() - phys);
            out[done..done + run].copy_from_slice(&file[phys..phys + run]);
            done += run;
        }
        done
    }

    /// Copy `data` into the file at logical offset `lo`, growing the file
    /// as needed.
    pub fn write(&self, file: &mut Vec<u8>, lo: u64, data: &[u8]) {
        let mut done = 0;
        while done < data.len() {
            let phys = self.physical(lo + done as u64) as usize;
            let tb = self.tile_bytes() as u64;
            let within = ((lo + done as u64) % tb) as usize;
            let run = self.entry_run(within).min(data.len() - done);
            if phys + run > file.len() {
                file.resize(phys + run, 0);
            }
            file[phys..phys + run].copy_from_slice(&data[done..done + run]);
            done += run;
        }
    }

    /// The physical `(offset, length)` runs that `len` logical bytes
    /// starting at logical offset `lo` map to, in logical order with
    /// physically-adjacent runs merged. This is the write-side plan the
    /// two-phase exchange splits at stripe boundaries — the payload for
    /// run *i* is the next `runs[i].1` bytes of the packed data.
    pub fn runs(&self, lo: u64, len: usize) -> Vec<(u64, usize)> {
        let mut out: Vec<(u64, usize)> = Vec::new();
        let mut done = 0;
        while done < len {
            let l = lo + done as u64;
            let phys = self.physical(l);
            let within = (l % self.tile_bytes() as u64) as usize;
            let run = self.entry_run(within).min(len - done);
            match out.last_mut() {
                Some((p, n)) if *p + *n as u64 == phys => *n += run,
                _ => out.push((phys, run)),
            }
            done += run;
        }
        out
    }

    /// Remaining bytes of the typemap entry containing logical-in-tile
    /// offset `within`.
    fn entry_run(&self, mut within: usize) -> usize {
        for &(p, _) in self.filetype.map().entries() {
            let s = p.size();
            if within < s {
                return s - within;
            }
            within -= s;
        }
        unreachable!()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datatype::TypeMap;

    #[test]
    fn default_view_is_identity() {
        let v = View::default();
        assert_eq!(v.physical(0), 0);
        assert_eq!(v.physical(17), 17);
    }

    #[test]
    fn displacement_shifts() {
        let byte = Datatype::primitive(Primitive::Byte);
        let v = View::new(100, byte.clone(), byte).unwrap();
        assert_eq!(v.physical(5), 105);
    }

    #[test]
    fn strided_view_maps_alternate_blocks() {
        // filetype: 4 bytes visible out of every 8 (rank 0 of a 2-rank
        // striping pattern).
        let byte = Datatype::primitive(Primitive::Byte);
        let ft = TypeMap::vector(1, 4, 8, &TypeMap::primitive(Primitive::Byte)).resized(0, 8);
        let v = View::new(0, byte, Datatype::new(ft)).unwrap();
        assert_eq!(v.physical(0), 0);
        assert_eq!(v.physical(3), 3);
        assert_eq!(v.physical(4), 8); // next tile
        assert_eq!(v.physical(7), 11);
    }

    #[test]
    fn view_read_write_roundtrip() {
        let byte = Datatype::primitive(Primitive::Byte);
        let ft = TypeMap::vector(1, 2, 4, &TypeMap::primitive(Primitive::Byte)).resized(0, 4);
        let v = View::new(1, byte, Datatype::new(ft)).unwrap();
        let mut file = Vec::new();
        v.write(&mut file, 0, &[1, 2, 3, 4]);
        // Physical layout: disp 1, entries at tile*4 + {0,1}:
        // offsets 1,2 then 5,6.
        assert_eq!(file, vec![0, 1, 2, 0, 0, 3, 4]);
        let mut out = [0u8; 4];
        assert_eq!(v.read(&file, 0, &mut out), 4);
        assert_eq!(out, [1, 2, 3, 4]);
        // Read past EOF is short.
        let mut out = [0u8; 8];
        assert_eq!(v.read(&file, 0, &mut out), 4);
    }

    #[test]
    fn runs_merge_contiguous_and_split_strided() {
        // Identity view: one merged run regardless of tile walking.
        let v = View::default();
        assert_eq!(v.runs(5, 12), vec![(5, 12)]);
        assert_eq!(v.runs(0, 0), Vec::<(u64, usize)>::new());
        // Strided view (4 of every 8 bytes, displacement 2): runs split
        // at tile gaps.
        let byte = Datatype::primitive(Primitive::Byte);
        let ft = TypeMap::vector(1, 4, 8, &TypeMap::primitive(Primitive::Byte)).resized(0, 8);
        let v = View::new(2, byte, Datatype::new(ft)).unwrap();
        assert_eq!(v.runs(0, 10), vec![(2, 4), (10, 4), (18, 2)]);
        // Mid-tile start.
        assert_eq!(v.runs(2, 4), vec![(4, 2), (10, 2)]);
    }

    #[test]
    fn etype_filetype_mismatch_rejected() {
        let i32t = Datatype::primitive(Primitive::I32);
        let odd = Datatype::new(TypeMap::contiguous(3, &TypeMap::primitive(Primitive::Byte)));
        assert!(View::new(0, i32t, odd).is_err());
    }
}
