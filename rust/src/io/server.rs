//! The rank-hosted file server: server-side application of `Io*` packets.
//!
//! Every MPI-IO operation is real transport traffic — the client injects
//! an `IoMeta`/`IoWrite`/`IoRead` packet ([`crate::p2p::start_io`]) and
//! the *server* rank's engine applies it to the simulated filesystem
//! (`fabric.files`) when its own progress loop processes the packet, then
//! replies with `IoDone`/`IoData`. Which rank serves depends on the mode:
//!
//! * **In-process** jobs: every rank is its own server
//!   ([`server_rank`] returns the caller's world rank). The packet still
//!   crosses the full wire path — chaos delay/reorder, the cost model and
//!   the mailbox all apply — but lands back on the issuing rank's own
//!   engine, whose `fabric.files` map is shared with every other rank.
//!   Self-serving keeps the job live: a dedicated server rank would stop
//!   progressing once its own closure returned.
//! * **Launched** (`shm`/`socket`) jobs: world rank 0 is the authoritative
//!   server — its process memory holds the one real filesystem; every
//!   other process's `files` map stays empty. Blocked clients keep
//!   processing inbound packets inside `wait_for`, and the launcher's
//!   final barrier keeps rank 0 alive until every client is done.
//!
//! Metadata ops ride one packet kind (`IoMeta`) with a small op code —
//! the codes below — rather than a kind per op: they are all
//! header-only request/scalar-reply exchanges with identical flow.

use super::view::View;
use crate::datatype::Datatype;
use crate::error::ErrorClass;
use crate::p2p::RankCtx;
use crate::transport::{PoolHandle, WireBytes};
use crate::{mpi_err, Result};
use crate::datatype::TypeMap;
use std::sync::atomic::Ordering;
use std::sync::Arc;

// ---- `IoMeta` op codes ----

/// Open: `arg = (handles << 8) | flags` — rank 0 of the opening
/// communicator opens `handles` handles at once. Replies the
/// `ErrorClass` code (0 = success).
pub const OP_OPEN: u8 = 0;
/// File size query: reply `value` = physical length in bytes.
pub const OP_SIZE: u8 = 1;
/// Truncate / zero-extend to `arg` bytes.
pub const OP_SET_SIZE: u8 = 2;
/// Grow to at least `arg` bytes (never shrinks).
pub const OP_PREALLOC: u8 = 3;
/// Delete: fails `NoSuchFile` / `FileInUse` by code.
pub const OP_DELETE: u8 = 4;
/// Shared-pointer fetch-and-add of `arg` etypes; reply `value` = old.
pub const OP_SHARED_BUMP: u8 = 5;
/// Shared-pointer store of `arg`.
pub const OP_SHARED_SET: u8 = 6;
/// Shared-pointer load; reply `value` = current.
pub const OP_SHARED_GET: u8 = 7;
/// Close: `arg = (handles << 8) | delete_on_close` — drops `handles`
/// open handles; removes the file when delete-on-close and none remain.
pub const OP_CLOSE: u8 = 8;

// Flag bits in the low byte of `arg` (OP_OPEN / OP_CLOSE).
pub const FLAG_CREATE: u64 = 1;
pub const FLAG_EXCL: u64 = 2;
pub const FLAG_DELETE_ON_CLOSE: u64 = 1;

/// Whether the served-file path is enabled (`FERROMPI_IO_SERVER`,
/// default on). With it off, `File::open` on a multi-process backend
/// refuses cleanly instead of routing through rank 0.
pub fn server_enabled() -> bool {
    std::env::var("FERROMPI_IO_SERVER").map_or(true, |v| v != "0")
}

/// The world rank that serves IO packets for this job (see module docs).
pub fn server_rank(ctx: &RankCtx) -> usize {
    if ctx.fabric.is_multiprocess() {
        0
    } else {
        ctx.world_rank
    }
}

/// Reconstruct the client's file view from the wire fields of an
/// `IoWrite`/`IoRead` packet. The etype is always byte on the wire: a
/// view's logical space is byte-addressed once offsets are scaled at the
/// client, so only (displacement, filetype) need to cross.
fn wire_view(disp: u64, map: &Arc<TypeMap>) -> View {
    View {
        displacement: disp,
        etype: Datatype::primitive(crate::datatype::Primitive::Byte),
        filetype: Datatype::from_shared(Arc::clone(map)),
    }
}

/// Apply one metadata op. Returns `(value, code)` for the `IoDone` reply;
/// a nonzero code is the `ErrorClass` the client surfaces.
pub(crate) fn serve_meta(ctx: &RankCtx, path: &str, op: u8, arg: u64) -> (u64, i32) {
    let files = &ctx.fabric.files;
    match op {
        OP_OPEN => {
            let handles = (arg >> 8) as u32;
            let mut files = files.lock().unwrap();
            let exists = files.contains_key(path);
            if exists && arg & FLAG_EXCL != 0 {
                return (0, ErrorClass::FileExists.code());
            }
            if !exists && arg & FLAG_CREATE == 0 {
                return (0, ErrorClass::NoSuchFile.code());
            }
            let node = files.entry(path.to_string()).or_default();
            node.open_count.fetch_add(handles, Ordering::SeqCst);
            (0, 0)
        }
        OP_CLOSE => {
            let handles = (arg >> 8) as u32;
            let mut files = files.lock().unwrap();
            let Some(node) = files.get(path) else {
                return (0, ErrorClass::NoSuchFile.code());
            };
            let remaining = node.open_count.fetch_sub(handles, Ordering::SeqCst) - handles;
            if arg & FLAG_DELETE_ON_CLOSE != 0 && remaining == 0 {
                files.remove(path);
            }
            (remaining as u64, 0)
        }
        OP_DELETE => {
            let mut files = files.lock().unwrap();
            match files.get(path) {
                None => (0, ErrorClass::NoSuchFile.code()),
                Some(node) if node.open_count.load(Ordering::SeqCst) > 0 => {
                    (0, ErrorClass::FileInUse.code())
                }
                Some(_) => {
                    files.remove(path);
                    (0, 0)
                }
            }
        }
        _ => {
            let node = {
                let files = files.lock().unwrap();
                match files.get(path) {
                    Some(n) => Arc::clone(n),
                    None => return (0, ErrorClass::NoSuchFile.code()),
                }
            };
            match op {
                OP_SIZE => (node.data.lock().unwrap().len() as u64, 0),
                OP_SET_SIZE => {
                    node.data.lock().unwrap().resize(arg as usize, 0);
                    (arg, 0)
                }
                OP_PREALLOC => {
                    let mut d = node.data.lock().unwrap();
                    if d.len() < arg as usize {
                        d.resize(arg as usize, 0);
                    }
                    (d.len() as u64, 0)
                }
                OP_SHARED_BUMP => {
                    let mut p = node.shared_ptr.lock().unwrap();
                    let old = *p;
                    *p += arg;
                    (old, 0)
                }
                OP_SHARED_SET => {
                    *node.shared_ptr.lock().unwrap() = arg;
                    (arg, 0)
                }
                OP_SHARED_GET => (*node.shared_ptr.lock().unwrap(), 0),
                other => (0, {
                    debug_assert!(false, "unknown io meta op {other}");
                    ErrorClass::UnsupportedOperation.code()
                }),
            }
        }
    }
}

/// Scatter an `IoWrite` payload through the view. Returns
/// `(bytes_written, code)`. The scatter writes straight from the shared
/// wire buffer into the file store (DMA-modeled, like `RmaPut`), so it is
/// not charged to `wire_bytes_copied`.
pub(crate) fn serve_write(
    ctx: &RankCtx,
    path: &str,
    disp: u64,
    map: &Arc<TypeMap>,
    lo: u64,
    data: &WireBytes,
) -> (u64, i32) {
    let node = {
        let files = ctx.fabric.files.lock().unwrap();
        match files.get(path) {
            Some(n) => Arc::clone(n),
            None => return (0, ErrorClass::NoSuchFile.code()),
        }
    };
    let view = wire_view(disp, map);
    let mut file = node.data.lock().unwrap();
    view.write(&mut file, lo, data);
    (data.len() as u64, 0)
}

/// Gather `nbytes` through the view into a pooled wire buffer (short at
/// EOF). The gather is the NIC-read half of the exchange (DMA-modeled,
/// uncharged), mirroring RMA get.
pub(crate) fn serve_read(
    ctx: &RankCtx,
    path: &str,
    disp: u64,
    map: &Arc<TypeMap>,
    lo: u64,
    nbytes: usize,
) -> Result<WireBytes> {
    let node = {
        let files = ctx.fabric.files.lock().unwrap();
        match files.get(path) {
            Some(n) => Arc::clone(n),
            None => return Err(mpi_err!(NoSuchFile, "read '{path}'")),
        }
    };
    let view = wire_view(disp, map);
    let mut out = vec![0u8; nbytes];
    let got = {
        let file = node.data.lock().unwrap();
        view.read(&file, lo, &mut out)
    };
    let mut wire = ctx.fabric.pool.take(got);
    wire.extend_from_slice(&out[..got]);
    Ok(wire.freeze())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::{Fabric, NetworkModel, NodeMap};
    use std::rc::Rc;

    fn ctx() -> Rc<RankCtx> {
        let fabric = Arc::new(Fabric::new(NodeMap::new(1, 2), NetworkModel::zero()));
        RankCtx::new(0, fabric)
    }

    #[test]
    fn open_close_lifecycle_and_codes() {
        let c = ctx();
        // Open without create: NoSuchFile.
        let (_, code) = serve_meta(&c, "/f", OP_OPEN, 2 << 8);
        assert_eq!(code, ErrorClass::NoSuchFile.code());
        // Create two handles.
        let (_, code) = serve_meta(&c, "/f", OP_OPEN, (2 << 8) | FLAG_CREATE);
        assert_eq!(code, 0);
        // Excl on an existing file refuses.
        let (_, code) = serve_meta(&c, "/f", OP_OPEN, (1 << 8) | FLAG_CREATE | FLAG_EXCL);
        assert_eq!(code, ErrorClass::FileExists.code());
        // Delete while open: FileInUse.
        let (_, code) = serve_meta(&c, "/f", OP_DELETE, 0);
        assert_eq!(code, ErrorClass::FileInUse.code());
        // Close both handles with delete-on-close: the file goes away.
        let (remaining, code) = serve_meta(&c, "/f", OP_CLOSE, (2 << 8) | FLAG_DELETE_ON_CLOSE);
        assert_eq!((remaining, code), (0, 0));
        assert!(c.fabric.files.lock().unwrap().is_empty());
    }

    #[test]
    fn size_shared_ptr_and_write_read_roundtrip() {
        let c = ctx();
        serve_meta(&c, "/f", OP_OPEN, (1 << 8) | FLAG_CREATE);
        let byte = Arc::new(TypeMap::primitive(crate::datatype::Primitive::Byte));
        let data = WireBytes::from_vec(vec![7u8; 16]);
        let (n, code) = serve_write(&c, "/f", 4, &byte, 0, &data);
        assert_eq!((n, code), (16, 0));
        assert_eq!(serve_meta(&c, "/f", OP_SIZE, 0), (20, 0));
        let got = serve_read(&c, "/f", 4, &byte, 0, 16).unwrap();
        assert_eq!(got.as_slice(), &[7u8; 16]);
        // Short read at EOF.
        let got = serve_read(&c, "/f", 0, &byte, 0, 64).unwrap();
        assert_eq!(got.len(), 20);
        // Shared pointer fetch-add.
        assert_eq!(serve_meta(&c, "/f", OP_SHARED_BUMP, 8), (0, 0));
        assert_eq!(serve_meta(&c, "/f", OP_SHARED_BUMP, 4), (8, 0));
        assert_eq!(serve_meta(&c, "/f", OP_SHARED_GET, 0), (12, 0));
        serve_meta(&c, "/f", OP_SHARED_SET, 0);
        assert_eq!(serve_meta(&c, "/f", OP_SHARED_GET, 0), (0, 0));
        // Ops against a missing path answer NoSuchFile, never panic.
        assert_eq!(serve_meta(&c, "/nope", OP_SIZE, 0).1, ErrorClass::NoSuchFile.code());
        assert_eq!(serve_write(&c, "/nope", 0, &byte, 0, &data).1, ErrorClass::NoSuchFile.code());
        assert!(serve_read(&c, "/nope", 0, &byte, 0, 4).is_err());
    }
}
