//! The IO component (MPI-4.0 chapter 14, `MPI_File_*`).
//!
//! Files live on a *file server rank* and every operation is real
//! transport traffic: clients inject `Io*` packets through the fabric
//! ([`server`]) and the server rank's engine applies them to the
//! simulated parallel filesystem — so chaos injection, flow control, the
//! cost model and the quiescence audit all cover the IO path. In-process
//! jobs self-serve (the filesystem is shared memory); launched `shm`/
//! `socket` jobs route through world rank 0. Views — displacement +
//! etype + filetype — are full typemap-based mappings from each rank's
//! logical element space to physical file bytes, so strided/subarray
//! file access behaves exactly like the standard describes.
//!
//! Collective writes aggregate through the two-phase exchange
//! ([`twophase`]); nonblocking variants return first-class
//! [`Request`](crate::request::Request)s driven by the progress engine.
//! See `docs/IO.md` for the full lifecycle and knob table.

pub mod file;
pub mod server;
pub mod twophase;
pub mod view;

pub use file::{AccessMode, File};
pub use view::View;
