//! The IO component (MPI-4.0 chapter 14, `MPI_File_*`).
//!
//! Files live in the fabric's simulated parallel filesystem (shared across
//! the job's ranks). Views — displacement + etype + filetype — are full
//! typemap-based mappings from each rank's logical element space to
//! physical file bytes, so strided/subarray file access behaves exactly
//! like the standard describes. Collective variants (`*_all`, ordered)
//! synchronize over the file's own communicator.

pub mod file;
pub mod view;

pub use file::{AccessMode, File};
pub use view::View;
