//! A4 — collective algorithm ablation: allreduce (recursive-doubling /
//! ring / reduce+bcast / hier / auto) and bcast (binomial / linear /
//! hier / auto) across message sizes; shows the crossovers the tuned
//! selection layer (`collective::tuned`) exists for, and what `auto`
//! actually picks at each size. Reshape with `FERROMPI_NODES` /
//! `FERROMPI_PPN`.

use ferrompi::collective::config::{self, AllreduceAlg, BcastAlg};
use ferrompi::datatype::{Datatype, Primitive};
use ferrompi::universe::Universe;
use ferrompi::util::stats::mean;
use ferrompi::util::table::Table;

const REPS: usize = 30;

fn time_allreduce(nodes: usize, ppn: usize, count: usize, alg: AllreduceAlg) -> f64 {
    config::set_allreduce_alg(alg);
    let times = Universe::new(nodes, ppn).run(move |comm| {
        let t = Datatype::primitive(Primitive::F32);
        let mine = vec![1.0f32; count];
        let mut out = vec![0.0f32; count];
        let sb = unsafe { std::slice::from_raw_parts(mine.as_ptr() as *const u8, count * 4) };
        let rb = unsafe { std::slice::from_raw_parts_mut(out.as_mut_ptr() as *mut u8, count * 4) };
        // warmup
        for _ in 0..3 {
            ferrompi::collective::allreduce(comm, Some(sb), rb, count, &t, &ferrompi::op::Op::SUM).unwrap();
        }
        ferrompi::collective::barrier(comm).unwrap();
        let t0 = comm.wtime();
        for _ in 0..REPS {
            ferrompi::collective::allreduce(comm, Some(sb), rb, count, &t, &ferrompi::op::Op::SUM).unwrap();
        }
        (comm.wtime() - t0) / REPS as f64
    });
    config::set_allreduce_alg(AllreduceAlg::Auto);
    mean(&times)
}

fn time_bcast(nodes: usize, ppn: usize, bytes: usize, alg: BcastAlg) -> f64 {
    config::set_bcast_alg(alg);
    let times = Universe::new(nodes, ppn).run(move |comm| {
        let t = Datatype::primitive(Primitive::Byte);
        let mut buf = vec![1u8; bytes];
        for _ in 0..3 {
            ferrompi::collective::bcast(comm, &mut buf, bytes, &t, 0).unwrap();
        }
        ferrompi::collective::barrier(comm).unwrap();
        let t0 = comm.wtime();
        for _ in 0..REPS {
            ferrompi::collective::bcast(comm, &mut buf, bytes, &t, 0).unwrap();
        }
        (comm.wtime() - t0) / REPS as f64
    });
    config::set_bcast_alg(BcastAlg::Auto);
    mean(&times)
}

fn main() {
    let u = Universe::from_env(4, 2);
    let (nodes, ppn) = (u.nodemap.nodes, u.nodemap.ppn);
    println!("\nA4 — allreduce algorithms, {nodes} nodes × {ppn} ppn (us/op):\n");
    let mut t = Table::new(&["f32 count", "rec-doubling", "ring", "reduce+bcast", "hier", "auto"]);
    for count in [16usize, 1024, 16384, 131072] {
        let rd = time_allreduce(nodes, ppn, count, AllreduceAlg::RecursiveDoubling);
        let ring = time_allreduce(nodes, ppn, count, AllreduceAlg::Ring);
        let rb = time_allreduce(nodes, ppn, count, AllreduceAlg::ReduceBcast);
        let hier = time_allreduce(nodes, ppn, count, AllreduceAlg::Hier);
        let auto = time_allreduce(nodes, ppn, count, AllreduceAlg::Auto);
        t.push(vec![
            count.to_string(),
            format!("{:.1}", rd * 1e6),
            format!("{:.1}", ring * 1e6),
            format!("{:.1}", rb * 1e6),
            format!("{:.1}", hier * 1e6),
            format!("{:.1}", auto * 1e6),
        ]);
    }
    println!("{}", t.to_markdown());

    println!("\nA4 — bcast algorithms, {nodes} nodes × {ppn} ppn (us/op):\n");
    let mut t = Table::new(&["bytes", "binomial", "linear", "hier", "auto"]);
    for bytes in [64usize, 4096, 262144] {
        let bin = time_bcast(nodes, ppn, bytes, BcastAlg::Binomial);
        let lin = time_bcast(nodes, ppn, bytes, BcastAlg::Linear);
        let hier = time_bcast(nodes, ppn, bytes, BcastAlg::Hier);
        let auto = time_bcast(nodes, ppn, bytes, BcastAlg::Auto);
        t.push(vec![
            bytes.to_string(),
            format!("{:.1}", bin * 1e6),
            format!("{:.1}", lin * 1e6),
            format!("{:.1}", hier * 1e6),
            format!("{:.1}", auto * 1e6),
        ]);
    }
    println!("{}", t.to_markdown());
    println!(
        "expected shape: rec-doubling wins small, ring wins large, hier wins small multi-node; \
         auto should track the per-row winner (binomial beats linear as p grows)"
    );
}
