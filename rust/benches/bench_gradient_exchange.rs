//! A6 — gradient exchange: allreduce bandwidth for the data-parallel
//! training pattern, chunked vs unchunked, per combine engine.
//!
//! For each payload × rank count × engine the sweep measures the same
//! persistent-pipeline allreduce twice: once with chunking suppressed
//! (threshold pushed above the payload) and once under the effective
//! threshold, so the `overlap_efficiency` column (unchunked time /
//! chunked time) isolates what the compute/transport overlap buys after
//! paying the chunking overhead. The combine pvars are sampled per run
//! and carried into the JSON so a regression in engine selection (e.g.
//! offload silently falling back) is visible in the artifact, not just
//! in wall-clock noise.
//!
//! Writes `BENCH_gradient_exchange.json` at the repo root (a CI
//! bench-smoke artifact). Set `FERROMPI_BENCH_QUICK=1` for the
//! seconds-scale subset.

use ferrompi::collective::config::{self, CombineEngine};
use ferrompi::coordinator::{write_gradient_json, GradientRow};
use ferrompi::modern::{Communicator, ReduceOp};
use ferrompi::tool::PvarSession;
use ferrompi::universe::Universe;
use std::time::Instant;

/// One universe run: `iters` pipelined allreduces of `count` f32 on
/// `ranks` in-process ranks. Returns rank 0's (mean seconds/iter,
/// combine pvars, chunk count).
struct Sample {
    mean_s: f64,
    combine_blocks: u64,
    combine_offloaded: u64,
    combine_fallbacks: u64,
    chunks_inflight_max: u64,
    nchunks: usize,
}

fn measure(ranks: usize, count: usize, iters: usize) -> Sample {
    let u = Universe::new(1, ranks);
    let per_rank = u.run(move |comm| {
        let m = Communicator::world(comm);
        let coll = m
            .persistent_all_reduce_chunked::<f32>(count, ReduceOp::Sum)
            .unwrap_or_else(|e| panic!("chunked allreduce init: {e}"));
        let pipe = coll.pipeline();
        let grad: Vec<f32> = (0..count).map(|i| (i % 97) as f32).collect();
        let mut out = vec![0f32; count];
        coll.write(&grad);
        pipe.run().unwrap(); // warmup iteration
        ferrompi::collective::barrier(comm).unwrap();
        let start = Instant::now();
        for _ in 0..iters {
            coll.write(&grad);
            pipe.start().and_then(|f| f.get()).unwrap_or_else(|e| panic!("allreduce: {e}"));
        }
        let mean_s = start.elapsed().as_secs_f64() / iters as f64;
        coll.read(&mut out);
        assert!(out[0].is_finite(), "reduction produced garbage");
        let s = PvarSession::create(comm);
        let read = |n| s.read(n).unwrap();
        (
            comm.rank(),
            Sample {
                mean_s,
                combine_blocks: read("combine_blocks"),
                combine_offloaded: read("combine_offloaded"),
                combine_fallbacks: read("combine_fallbacks"),
                chunks_inflight_max: read("chunks_inflight_max"),
                nchunks: coll.num_chunks(),
            },
        )
    });
    per_rank.into_iter().find(|(r, _)| *r == 0).expect("rank 0 measured").1
}

fn main() {
    let quick = std::env::var("FERROMPI_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let counts: Vec<usize> =
        if quick { vec![1 << 16] } else { vec![1 << 14, 1 << 16, 1 << 20] };
    let rank_counts: Vec<usize> = if quick { vec![2] } else { vec![2, 4] };
    let engines: Vec<CombineEngine> = if quick {
        vec![CombineEngine::Auto, CombineEngine::Scalar]
    } else {
        vec![
            CombineEngine::Auto,
            CombineEngine::Scalar,
            CombineEngine::Native,
            CombineEngine::Offload,
        ]
    };
    let iters = if quick { 3 } else { 10 };

    println!("A6 — gradient exchange: chunked vs unchunked allreduce per combine engine\n");
    let mut rows: Vec<GradientRow> = Vec::new();
    for &count in &counts {
        let payload = count * 4;
        for &ranks in &rank_counts {
            for &engine in &engines {
                config::set_combine_engine(engine);

                // Baseline: chunking suppressed for any realistic payload.
                config::set_chunk_threshold(1 << 62);
                let base = measure(ranks, count, iters);
                // Chunked: back to the env/default threshold.
                config::set_chunk_threshold(0);
                let chunked = measure(ranks, count, iters);

                let efficiency = base.mean_s / chunked.mean_s;
                println!(
                    "  {:>9} B × {ranks} ranks, {:<7}: unchunked {:>9.1} us, chunked {:>9.1} us \
                     ({} chunk(s), overlap {:.2}x)",
                    payload,
                    engine.label(),
                    base.mean_s * 1e6,
                    chunked.mean_s * 1e6,
                    chunked.nchunks,
                    efficiency,
                );
                rows.push(GradientRow {
                    payload_bytes: payload,
                    ranks,
                    engine: engine.label(),
                    chunked: false,
                    bytes_per_s: payload as f64 / base.mean_s,
                    overlap_efficiency: 1.0,
                    combine_blocks: base.combine_blocks,
                    combine_offloaded: base.combine_offloaded,
                    combine_fallbacks: base.combine_fallbacks,
                    chunks_inflight_max: base.chunks_inflight_max,
                });
                rows.push(GradientRow {
                    payload_bytes: payload,
                    ranks,
                    engine: engine.label(),
                    chunked: chunked.nchunks > 1,
                    bytes_per_s: payload as f64 / chunked.mean_s,
                    overlap_efficiency: efficiency,
                    combine_blocks: chunked.combine_blocks,
                    combine_offloaded: chunked.combine_offloaded,
                    combine_fallbacks: chunked.combine_fallbacks,
                    chunks_inflight_max: chunked.chunks_inflight_max,
                });
            }
        }
    }
    // Leave the process-global knobs the way we found them.
    config::set_combine_engine(CombineEngine::Auto);
    config::set_chunk_threshold(0);

    // Repo root = parent of the rust/ crate (CWD under `cargo bench` is
    // wherever cargo was invoked, so anchor on the manifest instead).
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate has a parent dir")
        .to_path_buf();
    let path = root.join("BENCH_gradient_exchange.json");
    write_gradient_json(&rows, &path).expect("write gradient JSON");
    println!("\nwrote {}", path.display());
}
