//! E1 — Figure 1, CI-sized: the mpiBench sweep through both interfaces,
//! reduced to a minutes-scale subset. `examples/mpibench.rs` runs the
//! paper-sized sweep.

use ferrompi::coordinator::{figure1_report, run_mpibench, MpiBenchConfig};

fn main() {
    let cfg = MpiBenchConfig::quick();
    eprintln!("bench_figure1 (quick subset; full sweep: cargo run --release --example mpibench)");
    let rows = run_mpibench(&cfg, |m| eprintln!("{m}"));
    let report = figure1_report(&rows);
    println!("{}", report.markdown);
    println!(
        "E1 headline: modern/raw geomean overhead = {:.4} (paper: ≈1.0)",
        report.overall_overhead
    );
}
