//! A1 — per-operation interface overhead decomposition: raw vs modern,
//! per op, at fixed shape (the paper reports only the geomean; this shows
//! where any overhead would live).

use ferrompi::coordinator::{run_mpibench, Interface, MpiBenchConfig, ALL_OPS};
use ferrompi::util::table::Table;

fn main() {
    let cfg = MpiBenchConfig {
        msg_lens: vec![1024],
        node_counts: vec![2],
        ppn: 2,
        reps: 5,
        iters: 10,
        interfaces: vec![Interface::Raw, Interface::Modern],
        ops: ALL_OPS.to_vec(),
    };
    let rows = run_mpibench(&cfg, |m| eprintln!("{m}"));
    let mut t = Table::new(&["op", "raw (us)", "modern (us)", "modern/raw"]);
    for op in ALL_OPS {
        let get = |iface| {
            rows.iter()
                .find(|r| r.op == op && r.interface == iface)
                .map(|r| r.mean_s)
                .unwrap_or(f64::NAN)
        };
        let (raw, modern) = (get(Interface::Raw), get(Interface::Modern));
        t.push(vec![
            op.label().into(),
            format!("{:.2}", raw * 1e6),
            format!("{:.2}", modern * 1e6),
            format!("{:.3}", modern / raw),
        ]);
    }
    println!("\nA1 — per-op interface overhead (1 KiB, 2 nodes × 2 ppn):\n");
    println!("{}", t.to_markdown());
}
