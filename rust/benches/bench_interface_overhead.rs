//! A1 — per-operation interface overhead decomposition: raw vs modern,
//! per op, at fixed shape (the paper reports only the geomean; this shows
//! where any overhead would live).
//!
//! Besides the human-readable table, writes the machine-readable
//! `BENCH_interface_overhead.json` at the repo root (op, shape, raw and
//! modern mean+stddev, modern/raw ratio) — the perf-trajectory seed and
//! the CI bench-smoke artifact — and reports allocation counts so the
//! overhead numbers demonstrably measure the interface, not the
//! allocator. Set `FERROMPI_BENCH_QUICK=1` for a seconds-scale shape.

use ferrompi::coordinator::{
    run_mpibench, write_overhead_json, Interface, MpiBenchConfig, ALL_OPS,
};
use ferrompi::util::alloc_count;
use ferrompi::util::table::Table;

#[global_allocator]
static ALLOC: alloc_count::CountingAlloc = alloc_count::CountingAlloc;

fn main() {
    let quick = std::env::var("FERROMPI_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let cfg = MpiBenchConfig {
        msg_lens: vec![1024],
        node_counts: vec![2],
        ppn: 2,
        reps: if quick { 2 } else { 5 },
        iters: if quick { 3 } else { 10 },
        interfaces: vec![Interface::Raw, Interface::Modern],
        ops: ALL_OPS.to_vec(),
    };
    let allocs_before = alloc_count::allocations();
    let rows = run_mpibench(&cfg, |m| eprintln!("{m}"));
    let allocs = alloc_count::allocations() - allocs_before;

    let mut t = Table::new(&["op", "raw (us)", "modern (us)", "modern/raw"]);
    for op in ALL_OPS {
        let get = |iface| {
            rows.iter()
                .find(|r| r.op == op && r.interface == iface)
                .map(|r| r.mean_s)
                .unwrap_or(f64::NAN)
        };
        let (raw, modern) = (get(Interface::Raw), get(Interface::Modern));
        t.push(vec![
            op.label().into(),
            format!("{:.2}", raw * 1e6),
            format!("{:.2}", modern * 1e6),
            format!("{:.3}", modern / raw),
        ]);
    }
    println!("\nA1 — per-op interface overhead (1 KiB, 2 nodes × 2 ppn):\n");
    println!("{}", t.to_markdown());
    // Per (op, msg, node count, interface): 2 warmup ops + reps timed
    // loops of `iters` ops each (see coordinator::mpibench::measure_job).
    let total_ops: usize = cfg.ops.len()
        * cfg.msg_lens.len()
        * cfg.node_counts.len()
        * cfg.interfaces.len()
        * (cfg.reps * cfg.iters + 2);
    println!(
        "allocator: {allocs} allocations across the sweep (~{:.0} per collective op incl. warmup)",
        allocs as f64 / total_ops as f64
    );

    // Repo root = parent of the rust/ crate (CWD under `cargo bench` is
    // wherever cargo was invoked, so anchor on the manifest instead).
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate has a parent dir")
        .join("BENCH_interface_overhead.json");
    write_overhead_json(&rows, &path).expect("write bench JSON");
    println!("wrote {}", path.display());
}
