//! A1 — per-operation interface overhead decomposition: raw vs modern,
//! per op, at fixed shape (the paper reports only the geomean; this shows
//! where any overhead would live).
//!
//! Besides the human-readable table, writes two machine-readable JSON
//! files at the repo root (both CI bench-smoke artifacts):
//!
//! * `BENCH_interface_overhead.json` — op, shape, raw and modern
//!   mean+stddev, modern/raw ratio (the perf-trajectory seed);
//! * `BENCH_tuned_collectives.json` — the flat-vs-hier-vs-auto
//!   trajectory: allreduce/bcast across multi-node shapes per algorithm,
//!   with modeled time and the per-op inter-node message split (the
//!   number hierarchical algorithms exist to shrink).
//!
//! Also reports allocation counts so the overhead numbers demonstrably
//! measure the interface, not the allocator. Set `FERROMPI_BENCH_QUICK=1`
//! for a seconds-scale shape; `FERROMPI_NODES`/`FERROMPI_PPN` reshape the
//! cluster without recompiling.

use ferrompi::coordinator::{
    run_algsweep, run_mpibench, write_overhead_json, write_tuned_json, Interface, MpiBenchConfig,
    ALL_OPS,
};
use ferrompi::util::alloc_count;
use ferrompi::util::table::Table;

#[global_allocator]
static ALLOC: alloc_count::CountingAlloc = alloc_count::CountingAlloc;

fn main() {
    let quick = std::env::var("FERROMPI_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let ppn = ferrompi::universe::Universe::from_env(2, 2).nodemap.ppn;
    let cfg = MpiBenchConfig {
        msg_lens: vec![1024],
        node_counts: if quick { vec![2] } else { vec![2, 4] },
        ppn,
        reps: if quick { 2 } else { 5 },
        iters: if quick { 3 } else { 10 },
        interfaces: vec![Interface::Raw, Interface::Modern],
        ops: ALL_OPS.to_vec(),
    };
    let allocs_before = alloc_count::allocations();
    let rows = run_mpibench(&cfg, |m| eprintln!("{m}"));
    let allocs = alloc_count::allocations() - allocs_before;

    let mut t = Table::new(&["op", "raw (us)", "modern (us)", "modern/raw"]);
    for op in ALL_OPS {
        let get = |iface| {
            rows.iter()
                .find(|r| r.op == op && r.interface == iface)
                .map(|r| r.mean_s)
                .unwrap_or(f64::NAN)
        };
        let (raw, modern) = (get(Interface::Raw), get(Interface::Modern));
        t.push(vec![
            op.label().into(),
            format!("{:.2}", raw * 1e6),
            format!("{:.2}", modern * 1e6),
            format!("{:.3}", modern / raw),
        ]);
    }
    println!("\nA1 — per-op interface overhead (1 KiB, 2 nodes × {ppn} ppn):\n");
    println!("{}", t.to_markdown());
    // Per (op, msg, node count, interface): 2 warmup ops + reps timed
    // loops of `iters` ops each (see coordinator::mpibench::measure_job).
    let total_ops: usize = cfg.ops.len()
        * cfg.msg_lens.len()
        * cfg.node_counts.len()
        * cfg.interfaces.len()
        * (cfg.reps * cfg.iters + 2);
    println!(
        "allocator: {allocs} allocations across the sweep (~{:.0} per collective op incl. warmup)",
        allocs as f64 / total_ops as f64
    );

    // The tuned-collective trajectory: flat vs hier vs auto over
    // multi-node shapes, with the per-op inter-node message split.
    let shapes: &[(usize, usize)] =
        if quick { &[(4, 2)] } else { &[(2, 2), (4, 2), (4, 4)] };
    let msg_lens: &[usize] = if quick { &[1024] } else { &[64, 1024, 1 << 17] };
    let sweep = run_algsweep(shapes, msg_lens, if quick { 3 } else { 10 }, |m| eprintln!("{m}"));
    let mut t = Table::new(&["op", "alg", "resolved", "nodes×ppn", "msg B", "us/op", "inter msgs/op", "msgs/op"]);
    for r in &sweep {
        t.push(vec![
            r.op.into(),
            r.alg.into(),
            r.resolved.into(),
            format!("{}x{}", r.nodes, r.ppn),
            r.msg_len.to_string(),
            format!("{:.1}", r.time_s * 1e6),
            format!("{:.1}", r.inter_msgs_per_op),
            format!("{:.1}", r.total_msgs_per_op),
        ]);
    }
    println!("\nA1b — tuned collectives, flat vs hier vs auto:\n");
    println!("{}", t.to_markdown());

    // Repo root = parent of the rust/ crate (CWD under `cargo bench` is
    // wherever cargo was invoked, so anchor on the manifest instead).
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate has a parent dir")
        .to_path_buf();
    let path = root.join("BENCH_interface_overhead.json");
    write_overhead_json(&rows, &path).expect("write bench JSON");
    println!("wrote {}", path.display());
    let path = root.join("BENCH_tuned_collectives.json");
    write_tuned_json(&sweep, &path).expect("write tuned JSON");
    println!("wrote {}", path.display());
}
