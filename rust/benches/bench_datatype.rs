//! A3 — datatype-reflection overhead: packing through the
//! `#[derive(DataType)]` typemap vs a hand-built `MPI_Type_create_struct`
//! vs raw memcpy of a contiguous type, plus the strided-column case.

use ferrompi::datatype::{pack, unpack, Datatype, Primitive, TypeMap};
use ferrompi::util::microbench::{quick, Bench};
// One import, two namespaces: the trait and the derive macro.
use ferrompi::DataType;

#[derive(Debug, Clone, Copy, Default, DataType)]
struct Particle {
    position: [f32; 3],
    velocity: [f32; 3],
    mass: f32,
    id: u64,
}

const N: usize = 1000;

fn main() {
    println!("\nA3 — pack/unpack cost: derive-reflected vs manual vs contiguous ({N} elements):\n");
    let mut b = Bench::new(quick());

    let particles = vec![Particle { position: [1.0; 3], velocity: [2.0; 3], mass: 3.0, id: 4 }; N];
    let src = unsafe {
        std::slice::from_raw_parts(particles.as_ptr() as *const u8, N * std::mem::size_of::<Particle>())
    };

    // Derived typemap (the paper's automatic reflection).
    let derived = Particle::datatype();
    b.run("pack: #[derive(DataType)] struct", || {
        let mut wire = Vec::with_capacity(N * derived.size());
        pack(derived.map(), src, N, &mut wire).unwrap();
        wire.len()
    });

    // Hand-built struct type (what the C interface forces you to write).
    let manual = {
        let f32m = TypeMap::primitive(Primitive::F32);
        let mut d = Datatype::new(TypeMap::structure(&[
            (std::mem::offset_of!(Particle, position) as isize, TypeMap::contiguous(3, &f32m), 1),
            (std::mem::offset_of!(Particle, velocity) as isize, TypeMap::contiguous(3, &f32m), 1),
            (std::mem::offset_of!(Particle, mass) as isize, f32m, 1),
            (std::mem::offset_of!(Particle, id) as isize, TypeMap::primitive(Primitive::U64), 1),
        ]).resized(0, std::mem::size_of::<Particle>() as isize));
        d.commit();
        d
    };
    assert_eq!(manual.size(), derived.size(), "both typemaps describe the same wire layout");
    assert!(
        manual.map().layout_eq(derived.map()),
        "reflection must reproduce the hand-built typemap entry-for-entry"
    );
    b.run("pack: manual MPI_Type_create_struct", || {
        let mut wire = Vec::with_capacity(N * manual.size());
        pack(manual.map(), src, N, &mut wire).unwrap();
        wire.len()
    });

    // Contiguous baseline: pure memcpy path.
    let floats = vec![1.0f32; N * 10];
    let fsrc = unsafe { std::slice::from_raw_parts(floats.as_ptr() as *const u8, N * 40) };
    let cont = <f32 as DataType>::datatype();
    b.run("pack: contiguous f32 (memcpy fast path)", || {
        let mut wire = Vec::with_capacity(N * 40);
        pack(cont.map(), fsrc, N * 10, &mut wire).unwrap();
        wire.len()
    });

    // Strided column out of a matrix (vector datatype).
    let mat = vec![1.0f32; N * 64];
    let msrc = unsafe { std::slice::from_raw_parts(mat.as_ptr() as *const u8, N * 256) };
    let mut col = Datatype::new(TypeMap::vector(N, 1, 64, &TypeMap::primitive(Primitive::F32)));
    col.commit();
    b.run("pack: strided column (vector type)", || {
        let mut wire = Vec::with_capacity(N * 4);
        pack(col.map(), msrc, 1, &mut wire).unwrap();
        wire.len()
    });

    // Unpack side for the derived case.
    let mut wire = Vec::new();
    pack(derived.map(), src, N, &mut wire).unwrap();
    let mut dst = vec![0u8; N * std::mem::size_of::<Particle>()];
    b.run("unpack: #[derive(DataType)] struct", || {
        unpack(derived.map(), &wire, &mut dst, N).unwrap()
    });

    let ratio = b.ratio("pack: #[derive(DataType)] struct", "pack: manual MPI_Type_create_struct").unwrap();
    println!("\nA3 headline: derive/manual pack ratio = {ratio:.3} (reflection is free at runtime: same typemap)");
}
