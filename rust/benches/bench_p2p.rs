//! Transport microbenchmarks: eager vs rendezvous ping-pong latency,
//! intra- vs inter-node, matching-engine behaviour under unexpected-
//! message floods, and — since the zero-copy refactor — allocation and
//! payload-copy counts on the message path (the substrate's hot paths,
//! used by the §Perf log).

use ferrompi::datatype::{Datatype, Primitive};
use ferrompi::universe::Universe;
use ferrompi::util::alloc_count;
use ferrompi::util::stats::mean;
use ferrompi::util::table::Table;

#[global_allocator]
static ALLOC: alloc_count::CountingAlloc = alloc_count::CountingAlloc;

const ITERS: usize = 500;

/// One-way latency plus steady-state allocation count per iteration
/// (measured on rank 0 across the timed loop, after warmup has populated
/// the wire-buffer pool) and the job's pool counters.
struct PingPong {
    one_way_s: f64,
    allocs_per_iter: f64,
    pool: ferrompi::transport::PoolStats,
}

fn pingpong(nodes: usize, ppn: usize, bytes: usize) -> PingPong {
    let (times, fabric) = Universe::new(nodes, ppn).run_with_stats(move |comm| {
        let t = Datatype::primitive(Primitive::Byte);
        let payload = vec![1u8; bytes];
        let mut buf = vec![0u8; bytes];
        let me = comm.rank();
        let peer = if me == 0 { (comm.size() - 1) as i32 } else { 0 };
        if me != 0 && me != comm.size() - 1 {
            return (f64::NAN, f64::NAN);
        }
        // warmup (also fills the buffer pool: the timed loop recycles)
        for _ in 0..10 {
            if me == 0 {
                comm.send(&payload, bytes, &t, peer, 0).unwrap();
                comm.recv(&mut buf, bytes, &t, peer, 0).unwrap();
            } else {
                comm.recv(&mut buf, bytes, &t, peer, 0).unwrap();
                comm.send(&payload, bytes, &t, peer, 0).unwrap();
            }
        }
        let allocs0 = alloc_count::allocations();
        let t0 = comm.wtime();
        for _ in 0..ITERS {
            if me == 0 {
                comm.send(&payload, bytes, &t, peer, 0).unwrap();
                comm.recv(&mut buf, bytes, &t, peer, 0).unwrap();
            } else {
                comm.recv(&mut buf, bytes, &t, peer, 0).unwrap();
                comm.send(&payload, bytes, &t, peer, 0).unwrap();
            }
        }
        let dt = (comm.wtime() - t0) / ITERS as f64 / 2.0; // one-way
        let allocs = (alloc_count::allocations() - allocs0) as f64 / ITERS as f64;
        (dt, allocs)
    });
    let mut lat = Vec::new();
    // Both endpoint ranks count the whole process's allocations, so take
    // the first endpoint's reading rather than summing.
    let mut allocs = f64::NAN;
    for (t, a) in times {
        if !t.is_nan() {
            lat.push(t);
            if allocs.is_nan() {
                allocs = a;
            }
        }
    }
    PingPong { one_way_s: mean(&lat), allocs_per_iter: allocs, pool: fabric.pool.stats() }
}

fn unexpected_flood(depth: usize) -> f64 {
    // Rank 0 sends `depth` messages with distinct tags before rank 1
    // posts any receive; rank 1 then receives them in REVERSE tag order,
    // forcing worst-case unexpected-queue scans.
    let times = Universe::test(2).run(move |comm| {
        let t = Datatype::primitive(Primitive::Byte);
        let payload = [1u8; 8];
        if comm.rank() == 0 {
            for tag in 0..depth as i32 {
                comm.send(&payload, 8, &t, 1, tag).unwrap();
            }
            0.0
        } else {
            // Wait until everything is queued.
            while comm.rank_ctx().matcher.borrow().unexpected_len() < depth {
                ferrompi::p2p::progress(comm.rank_ctx()).unwrap();
            }
            let t0 = std::time::Instant::now();
            let mut buf = [0u8; 8];
            for tag in (0..depth as i32).rev() {
                comm.recv(&mut buf, 8, &t, 0, tag).unwrap();
            }
            t0.elapsed().as_secs_f64() / depth as f64
        }
    });
    times[1]
}

fn main() {
    println!("\np2p — one-way latency (us), eager (≤64 KiB) vs rendezvous (>64 KiB),");
    println!("with per-iteration allocation count and pool/copy telemetry");
    println!("(i/e = the separate intra-node and inter-node jobs' fabrics):\n");
    let mut t = Table::new(&[
        "bytes",
        "intra-node (us)",
        "inter-node (us)",
        "allocs/iter i/e",
        "pool recycled i/e",
        "pool allocated i/e",
        "bytes CPU-copied i/e",
    ]);
    for bytes in [8usize, 1024, 65536, 65537, 262144] {
        let intra = pingpong(1, 2, bytes);
        let inter = pingpong(2, 1, bytes);
        t.push(vec![
            bytes.to_string(),
            format!("{:.2}", intra.one_way_s * 1e6),
            format!("{:.2}", inter.one_way_s * 1e6),
            format!("{:.1}/{:.1}", intra.allocs_per_iter, inter.allocs_per_iter),
            format!("{}/{}", intra.pool.recycled, inter.pool.recycled),
            format!("{}/{}", intra.pool.allocated, inter.pool.allocated),
            format!("{}/{}", intra.pool.copied_bytes, inter.pool.copied_bytes),
        ]);
    }
    println!("{}", t.to_markdown());
    println!(
        "(contiguous payloads keep `bytes CPU-copied` at 0 — the zero-copy \
         fast path; `pool allocated` stays flat while `pool recycled` grows \
         with iterations.)"
    );

    println!("\nmatching engine — unexpected-queue scan cost (ns per recv, reverse order):\n");
    let mut t = Table::new(&["queue depth", "ns/recv"]);
    for depth in [4usize, 64, 512] {
        t.push(vec![depth.to_string(), format!("{:.0}", unexpected_flood(depth) * 1e9)]);
    }
    println!("{}", t.to_markdown());
}
