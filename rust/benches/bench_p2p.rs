//! Transport microbenchmarks: eager vs rendezvous ping-pong latency,
//! intra- vs inter-node, matching-engine behaviour under unexpected-
//! message floods, and — since the zero-copy refactor — allocation and
//! payload-copy counts on the message path (the substrate's hot paths,
//! used by the §Perf log).

use ferrompi::coordinator::{write_transport_json, TransportRow};
use ferrompi::datatype::{Datatype, Primitive};
use ferrompi::universe::Universe;
use ferrompi::util::alloc_count;
use ferrompi::util::stats::mean;
use ferrompi::util::table::Table;
use std::sync::atomic::Ordering;

#[global_allocator]
static ALLOC: alloc_count::CountingAlloc = alloc_count::CountingAlloc;

const ITERS: usize = 500;

/// Message sizes for the cross-backend sweep (matches the
/// `builtin:pingpong` worker's default list).
const TRANSPORT_BYTES: [usize; 3] = [8, 1024, 65536];

/// One-way latency plus steady-state allocation count per iteration
/// (measured on rank 0 across the timed loop, after warmup has populated
/// the wire-buffer pool) and the job's pool counters.
struct PingPong {
    one_way_s: f64,
    allocs_per_iter: f64,
    pool: ferrompi::transport::PoolStats,
    /// Backend counters (the `backend_frames_tx` / `backend_bytes_tx`
    /// pvars) — on the in-process backend every packet counts, with zero
    /// framing bytes beyond the payload.
    backend_frames_tx: u64,
    backend_bytes_tx: u64,
    /// Flow-control counters (docs/FLOWCONTROL.md). A ping-pong holds one
    /// message in flight per direction, so stalls/demotions here are a
    /// regression signal, not expected behaviour; the mailbox watermark
    /// records how deep the bounded mailbox actually got.
    credits_stalled: u64,
    eager_demoted: u64,
    mailbox_hwm: u64,
}

fn pingpong(nodes: usize, ppn: usize, bytes: usize) -> PingPong {
    let (times, fabric) = Universe::new(nodes, ppn).run_with_stats(move |comm| {
        let t = Datatype::primitive(Primitive::Byte);
        let payload = vec![1u8; bytes];
        let mut buf = vec![0u8; bytes];
        let me = comm.rank();
        let peer = if me == 0 { (comm.size() - 1) as i32 } else { 0 };
        if me != 0 && me != comm.size() - 1 {
            return (f64::NAN, f64::NAN);
        }
        // warmup (also fills the buffer pool: the timed loop recycles)
        for _ in 0..10 {
            if me == 0 {
                comm.send(&payload, bytes, &t, peer, 0).unwrap();
                comm.recv(&mut buf, bytes, &t, peer, 0).unwrap();
            } else {
                comm.recv(&mut buf, bytes, &t, peer, 0).unwrap();
                comm.send(&payload, bytes, &t, peer, 0).unwrap();
            }
        }
        let allocs0 = alloc_count::allocations();
        let t0 = comm.wtime();
        for _ in 0..ITERS {
            if me == 0 {
                comm.send(&payload, bytes, &t, peer, 0).unwrap();
                comm.recv(&mut buf, bytes, &t, peer, 0).unwrap();
            } else {
                comm.recv(&mut buf, bytes, &t, peer, 0).unwrap();
                comm.send(&payload, bytes, &t, peer, 0).unwrap();
            }
        }
        let dt = (comm.wtime() - t0) / ITERS as f64 / 2.0; // one-way
        let allocs = (alloc_count::allocations() - allocs0) as f64 / ITERS as f64;
        (dt, allocs)
    });
    let mut lat = Vec::new();
    // Both endpoint ranks count the whole process's allocations, so take
    // the first endpoint's reading rather than summing.
    let mut allocs = f64::NAN;
    for (t, a) in times {
        if !t.is_nan() {
            lat.push(t);
            if allocs.is_nan() {
                allocs = a;
            }
        }
    }
    PingPong {
        one_way_s: mean(&lat),
        allocs_per_iter: allocs,
        pool: fabric.pool.stats(),
        backend_frames_tx: fabric.stats.backend.frames_tx.load(Ordering::Relaxed),
        backend_bytes_tx: fabric.stats.backend.bytes_tx.load(Ordering::Relaxed),
        credits_stalled: fabric.stats.credits_stalled.load(Ordering::Relaxed),
        eager_demoted: fabric.stats.eager_demoted.load(Ordering::Relaxed),
        mailbox_hwm: fabric.stats.mailbox_hwm.load(Ordering::Relaxed),
    }
}

/// Run `ferrompi-launch -n 2 --backend <b> builtin:pingpong` and parse
/// the `backend,bytes,one_way_s,credits_stalled,eager_demoted,
/// mailbox_hwm` CSV it appends. Returns `None` (with a
/// note) when the launcher binary is unavailable (e.g. a bench run that
/// didn't build bins) or the job fails — the sweep degrades to whatever
/// backends it can measure rather than aborting the whole bench.
fn launched_pingpong(backend: &'static str) -> Option<Vec<TransportRow>> {
    let launcher = match option_env!("CARGO_BIN_EXE_ferrompi-launch") {
        Some(p) => p,
        None => {
            println!("({backend}: skipped — launcher binary not built into this bench)");
            return None;
        }
    };
    let bytes_arg =
        TRANSPORT_BYTES.iter().map(|b| b.to_string()).collect::<Vec<_>>().join(",");
    let out = std::env::temp_dir().join(format!("ferrompi-pingpong-{}.csv", std::process::id()));
    let _ = std::fs::remove_file(&out);
    let status = std::process::Command::new(launcher)
        .args(["-n", "2", "--backend", backend, "builtin:pingpong", "--out"])
        .arg(&out)
        .args(["--bytes", &bytes_arg, "--iters", "200"])
        .status();
    let rows = match status {
        Ok(s) if s.success() => {
            let csv = std::fs::read_to_string(&out).unwrap_or_default();
            csv.lines()
                .filter_map(|line| {
                    let mut f = line.split(',');
                    let (b, nb, s) = (f.next()?, f.next()?, f.next()?);
                    if b != backend {
                        return None;
                    }
                    // Flow columns default to 0 so a CSV from an older
                    // worker still parses.
                    let mut counter = || f.next().and_then(|v| v.parse().ok()).unwrap_or(0);
                    Some(TransportRow {
                        backend,
                        bytes: nb.parse().ok()?,
                        one_way_s: s.parse().ok()?,
                        credits_stalled: counter(),
                        eager_demoted: counter(),
                        mailbox_hwm: counter(),
                    })
                })
                .collect()
        }
        Ok(s) => {
            println!("({backend}: skipped — launched job exited with {s})");
            return None;
        }
        Err(e) => {
            println!("({backend}: skipped — could not spawn launcher: {e})");
            return None;
        }
    };
    let _ = std::fs::remove_file(&out);
    Some(rows)
}

fn unexpected_flood(depth: usize) -> f64 {
    // Rank 0 sends `depth` messages with distinct tags before rank 1
    // posts any receive; rank 1 then receives them in REVERSE tag order,
    // forcing worst-case unexpected-queue scans.
    let times = Universe::test(2).run(move |comm| {
        let t = Datatype::primitive(Primitive::Byte);
        let payload = [1u8; 8];
        if comm.rank() == 0 {
            for tag in 0..depth as i32 {
                comm.send(&payload, 8, &t, 1, tag).unwrap();
            }
            0.0
        } else {
            // Wait until everything is queued.
            while comm.rank_ctx().matcher.borrow().unexpected_len() < depth {
                ferrompi::p2p::progress(comm.rank_ctx()).unwrap();
            }
            let t0 = std::time::Instant::now();
            let mut buf = [0u8; 8];
            for tag in (0..depth as i32).rev() {
                comm.recv(&mut buf, 8, &t, 0, tag).unwrap();
            }
            t0.elapsed().as_secs_f64() / depth as f64
        }
    });
    times[1]
}

fn main() {
    println!("\np2p — one-way latency (us), eager (≤64 KiB) vs rendezvous (>64 KiB),");
    println!("with per-iteration allocation count and pool/copy telemetry");
    println!("(i/e = the separate intra-node and inter-node jobs' fabrics):\n");
    let mut t = Table::new(&[
        "bytes",
        "intra-node (us)",
        "inter-node (us)",
        "allocs/iter i/e",
        "pool recycled i/e",
        "pool allocated i/e",
        "bytes CPU-copied i/e",
        "backend frames tx i/e",
        "backend bytes tx i/e",
    ]);
    let mut transport = Vec::new();
    for bytes in [8usize, 1024, 65536, 65537, 262144] {
        let intra = pingpong(1, 2, bytes);
        let inter = pingpong(2, 1, bytes);
        if TRANSPORT_BYTES.contains(&bytes) {
            transport.push(TransportRow {
                backend: "inproc",
                bytes,
                one_way_s: intra.one_way_s,
                credits_stalled: intra.credits_stalled,
                eager_demoted: intra.eager_demoted,
                mailbox_hwm: intra.mailbox_hwm,
            });
        }
        t.push(vec![
            bytes.to_string(),
            format!("{:.2}", intra.one_way_s * 1e6),
            format!("{:.2}", inter.one_way_s * 1e6),
            format!("{:.1}/{:.1}", intra.allocs_per_iter, inter.allocs_per_iter),
            format!("{}/{}", intra.pool.recycled, inter.pool.recycled),
            format!("{}/{}", intra.pool.allocated, inter.pool.allocated),
            format!("{}/{}", intra.pool.copied_bytes, inter.pool.copied_bytes),
            format!("{}/{}", intra.backend_frames_tx, inter.backend_frames_tx),
            format!("{}/{}", intra.backend_bytes_tx, inter.backend_bytes_tx),
        ]);
    }
    println!("{}", t.to_markdown());
    println!(
        "(contiguous payloads keep `bytes CPU-copied` at 0 — the zero-copy \
         fast path; `pool allocated` stays flat while `pool recycled` grows \
         with iterations.)"
    );

    println!("\nmatching engine — unexpected-queue scan cost (ns per recv, reverse order):\n");
    let mut t = Table::new(&["queue depth", "ns/recv"]);
    for depth in [4usize, 64, 512] {
        t.push(vec![depth.to_string(), format!("{:.0}", unexpected_flood(depth) * 1e9)]);
    }
    println!("{}", t.to_markdown());

    // Cross-backend sweep: the inproc rows above measured in-process;
    // shm and socket measured by launcher-spawned 2-rank jobs on this
    // host. Real wall-clock on real transports, so absolute numbers are
    // machine-dependent — the artifact exists to compare the backends
    // against each other on one machine.
    println!("\ntransport backends — one-way latency (us), 2 ranks on this host:\n");
    #[cfg(unix)]
    if let Some(rows) = launched_pingpong("shm") {
        transport.extend(rows);
    }
    if let Some(rows) = launched_pingpong("socket") {
        transport.extend(rows);
    }
    let mut t = Table::new(&[
        "backend",
        "bytes",
        "one-way (us)",
        "credits stalled",
        "eager demoted",
        "mailbox hwm",
    ]);
    for r in &transport {
        t.push(vec![
            r.backend.into(),
            r.bytes.to_string(),
            format!("{:.2}", r.one_way_s * 1e6),
            r.credits_stalled.to_string(),
            r.eager_demoted.to_string(),
            r.mailbox_hwm.to_string(),
        ]);
    }
    println!("{}", t.to_markdown());
    println!(
        "(flow-control columns — docs/FLOWCONTROL.md — should read 0/0/small \
         for a ping-pong: one message in flight never exhausts a credit \
         window.)"
    );

    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate has a parent dir")
        .to_path_buf();
    let path = root.join("BENCH_transport.json");
    write_transport_json(&transport, &path).expect("write transport JSON");
    println!("wrote {}", path.display());
}
