//! Transport microbenchmarks: eager vs rendezvous ping-pong latency,
//! intra- vs inter-node, and matching-engine behaviour under unexpected-
//! message floods (the substrate's hot paths, used by the §Perf log).

use ferrompi::datatype::{Datatype, Primitive};
use ferrompi::universe::Universe;
use ferrompi::util::stats::mean;
use ferrompi::util::table::Table;

const ITERS: usize = 500;

fn pingpong(nodes: usize, ppn: usize, bytes: usize) -> f64 {
    let times = Universe::new(nodes, ppn).run(move |comm| {
        let t = Datatype::primitive(Primitive::Byte);
        let payload = vec![1u8; bytes];
        let mut buf = vec![0u8; bytes];
        let me = comm.rank();
        let peer = if me == 0 { (comm.size() - 1) as i32 } else { 0 };
        if me != 0 && me != comm.size() - 1 {
            return f64::NAN;
        }
        // warmup
        for _ in 0..10 {
            if me == 0 {
                comm.send(&payload, bytes, &t, peer, 0).unwrap();
                comm.recv(&mut buf, bytes, &t, peer, 0).unwrap();
            } else {
                comm.recv(&mut buf, bytes, &t, peer, 0).unwrap();
                comm.send(&payload, bytes, &t, peer, 0).unwrap();
            }
        }
        let t0 = comm.wtime();
        for _ in 0..ITERS {
            if me == 0 {
                comm.send(&payload, bytes, &t, peer, 0).unwrap();
                comm.recv(&mut buf, bytes, &t, peer, 0).unwrap();
            } else {
                comm.recv(&mut buf, bytes, &t, peer, 0).unwrap();
                comm.send(&payload, bytes, &t, peer, 0).unwrap();
            }
        }
        (comm.wtime() - t0) / ITERS as f64 / 2.0 // one-way
    });
    mean(&times.into_iter().filter(|t| !t.is_nan()).collect::<Vec<_>>())
}

fn unexpected_flood(depth: usize) -> f64 {
    // Rank 0 sends `depth` messages with distinct tags before rank 1
    // posts any receive; rank 1 then receives them in REVERSE tag order,
    // forcing worst-case unexpected-queue scans.
    let times = Universe::test(2).run(move |comm| {
        let t = Datatype::primitive(Primitive::Byte);
        let payload = [1u8; 8];
        if comm.rank() == 0 {
            for tag in 0..depth as i32 {
                comm.send(&payload, 8, &t, 1, tag).unwrap();
            }
            0.0
        } else {
            // Wait until everything is queued.
            while comm.rank_ctx().matcher.borrow().unexpected_len() < depth {
                ferrompi::p2p::progress(comm.rank_ctx()).unwrap();
            }
            let t0 = std::time::Instant::now();
            let mut buf = [0u8; 8];
            for tag in (0..depth as i32).rev() {
                comm.recv(&mut buf, 8, &t, 0, tag).unwrap();
            }
            t0.elapsed().as_secs_f64() / depth as f64
        }
    });
    times[1]
}

fn main() {
    println!("\np2p — one-way latency (us), eager (≤64 KiB) vs rendezvous (>64 KiB):\n");
    let mut t = Table::new(&["bytes", "intra-node", "inter-node"]);
    for bytes in [8usize, 1024, 65536, 65537, 262144] {
        let intra = pingpong(1, 2, bytes);
        let inter = pingpong(2, 1, bytes);
        t.push(vec![
            bytes.to_string(),
            format!("{:.2}", intra * 1e6),
            format!("{:.2}", inter * 1e6),
        ]);
    }
    println!("{}", t.to_markdown());

    println!("\nmatching engine — unexpected-queue scan cost (ns per recv, reverse order):\n");
    let mut t = Table::new(&["queue depth", "ns/recv"]);
    for depth in [4usize, 64, 512] {
        t.push(vec![depth.to_string(), format!("{:.0}", unexpected_flood(depth) * 1e9)]);
    }
    println!("{}", t.to_markdown());
}
