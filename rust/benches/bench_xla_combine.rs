//! A5 — native vs XLA-offloaded reduction combine: the local combine step
//! of Reduce/Allreduce computed by the native Rust loop vs the AOT
//! Pallas kernel through PJRT (per-call dispatch cost vs throughput).

use ferrompi::datatype::{Primitive, TypeMap};
use ferrompi::op::{Op, OpKind};
use ferrompi::runtime;
use ferrompi::util::microbench::{quick, Bench};

fn main() {
    if !runtime::artifacts_available() {
        eprintln!("A5 skipped: artifacts not built (run `make artifacts`)");
        return;
    }
    runtime::engine().unwrap().warmup().unwrap();
    println!("\nA5 — local combine: native Rust vs AOT-Pallas-via-PJRT (f32 sum):\n");
    let mut b = Bench::new(quick());
    let map = TypeMap::primitive(Primitive::F32);
    let xla = runtime::xla_op(OpKind::Sum).unwrap();

    for count in [256usize, 4096, 65536] {
        let input: Vec<u8> = (0..count).flat_map(|i| (i as f32).to_le_bytes()).collect();
        let base: Vec<u8> = (0..count).flat_map(|i| (2.0 * i as f32).to_le_bytes()).collect();

        let mut inout = base.clone();
        b.run(&format!("native sum, {count} f32"), || {
            inout.copy_from_slice(&base);
            Op::SUM.apply(&map, &input, &mut inout, count).unwrap();
        });

        let mut inout2 = base.clone();
        b.run(&format!("xla    sum, {count} f32"), || {
            inout2.copy_from_slice(&base);
            xla.apply(&map, &input, &mut inout2, count).unwrap();
        });
        assert_eq!(inout, inout2, "both paths agree");

        let r = b
            .ratio(&format!("xla    sum, {count} f32"), &format!("native sum, {count} f32"))
            .unwrap();
        println!("  -> xla/native at {count}: {r:.1}x (PJRT dispatch amortizes with size)\n");
    }
    println!(
        "note: interpret-mode CPU timings — on TPU the xla path wins at scale; \
         see DESIGN.md §Hardware-Adaptation for the VMEM/VPU estimate"
    );
}
