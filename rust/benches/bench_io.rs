//! A7 — MPI-IO: collective checkpoint-write bandwidth through the wire
//! path, independent vs two-phase vs async-overlapped.
//!
//! For each payload × rank count the sweep times the same striped
//! collective write three ways: `independent` (two-phase aggregation
//! off — every rank's stripes go straight to the file server),
//! `twophase` (collective buffering through pool-allocated exchange
//! stripes), and `async` (`iwrite_at_all` posted, a compute kernel run
//! against the in-flight request, then completed). The IO pvars are
//! sampled per run and carried into the JSON, so a regression in the
//! aggregation path (staging suddenly charged where DMA should be, or
//! the exchange silently bypassed) is visible in the artifact, not just
//! in wall-clock noise.
//!
//! Writes `BENCH_io.json` at the repo root (a CI bench-smoke artifact).
//! Set `FERROMPI_BENCH_QUICK=1` for the seconds-scale subset.

use ferrompi::coordinator::{write_io_json, IoRow};
use ferrompi::datatype::{Datatype, Primitive, TypeMap};
use ferrompi::io::{AccessMode, File};
use ferrompi::tool::PvarSession;
use ferrompi::universe::Universe;
use std::time::Instant;

/// One universe run: `iters` collective writes of `len` bytes per rank.
/// Returns rank 0's mean seconds/iter plus the job's IO pvars.
struct Sample {
    mean_s: f64,
    io_reads: u64,
    io_writes: u64,
    io_aggregated_bytes: u64,
    wire_bytes_copied: u64,
}

fn measure(ranks: usize, len: usize, iters: usize, mode: &'static str) -> Sample {
    let u = Universe::new(1, ranks);
    let per_rank = u.run(move |comm| {
        let me = comm.rank();
        let pn = comm.size();
        let byte = Datatype::primitive(Primitive::Byte);
        let f = File::open(comm, "/bench/ckpt", AccessMode::read_write().with_delete_on_close())
            .unwrap();
        f.set_twophase(Some(mode != "independent"));
        // Block-cyclic striping: rank me owns one len-byte block of every
        // pn*len window — the classic checkpoint layout two-phase
        // aggregation exists for.
        let ft = Datatype::new(
            TypeMap::vector(1, len, len as isize, &TypeMap::primitive(Primitive::Byte))
                .resized(0, (pn * len) as isize),
        );
        f.set_view((me * len) as u64, &byte, &ft).unwrap();
        let payload: Vec<u8> = (0..len).map(|i| (i as u64 * 167 + me as u64) as u8).collect();
        // Warmup iteration, then the timed window.
        f.write_at_all(0, &payload, len, &byte).unwrap();
        ferrompi::collective::barrier(comm).unwrap();
        let start = Instant::now();
        let mut overlap_sink = 0u64;
        for _ in 0..iters {
            if mode == "async" {
                let req = f.iwrite_at_all(0, &payload, len, &byte).unwrap();
                // The "compute" the posted write overlaps with.
                overlap_sink = overlap_sink
                    .wrapping_add(payload.iter().map(|&b| b as u64).sum::<u64>());
                req.wait().unwrap();
            } else {
                f.write_at_all(0, &payload, len, &byte).unwrap();
            }
        }
        let mean_s = start.elapsed().as_secs_f64() / iters as f64;
        std::hint::black_box(overlap_sink);
        let s = PvarSession::create(comm);
        let read = |n| s.read(n).unwrap();
        let sample = Sample {
            mean_s,
            io_reads: read("io_reads"),
            io_writes: read("io_writes"),
            io_aggregated_bytes: read("io_aggregated_bytes"),
            wire_bytes_copied: read("wire_bytes_copied"),
        };
        f.close().unwrap();
        (me, sample)
    });
    per_rank.into_iter().find(|(r, _)| *r == 0).expect("rank 0 measured").1
}

fn main() {
    let quick = std::env::var("FERROMPI_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let payloads: Vec<usize> =
        if quick { vec![1 << 16] } else { vec![1 << 14, 1 << 18, 1 << 20] };
    let rank_counts: Vec<usize> = if quick { vec![4] } else { vec![2, 4] };
    let iters = if quick { 3 } else { 10 };

    println!("A7 — MPI-IO: independent vs two-phase vs async collective writes\n");
    let mut rows: Vec<IoRow> = Vec::new();
    for &len in &payloads {
        for &ranks in &rank_counts {
            for mode in ["independent", "twophase", "async"] {
                let s = measure(ranks, len, iters, mode);
                let agg = (ranks * len) as f64 / s.mean_s;
                println!(
                    "  {:>9} B × {ranks} ranks, {mode:<11}: {:>9.1} us/iter \
                     ({:>7.1} MB/s aggregate, staged {} B)",
                    len,
                    s.mean_s * 1e6,
                    agg / 1e6,
                    s.io_aggregated_bytes,
                );
                rows.push(IoRow {
                    mode,
                    payload_bytes: len,
                    ranks,
                    bytes_per_s: agg,
                    io_reads: s.io_reads,
                    io_writes: s.io_writes,
                    io_aggregated_bytes: s.io_aggregated_bytes,
                    wire_bytes_copied: s.wire_bytes_copied,
                });
            }
        }
    }

    // Repo root = parent of the rust/ crate (CWD under `cargo bench` is
    // wherever cargo was invoked, so anchor on the manifest instead).
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate has a parent dir")
        .to_path_buf();
    let path = root.join("BENCH_io.json");
    write_io_json(&rows, &path).expect("write io JSON");
    println!("\nwrote {}", path.display());
}
