//! A2 — futures-vs-raw-wait overhead: the same nonblocking ping-pong
//! through (a) raw isend/irecv + wait handles, (b) modern requests,
//! (c) modern futures with a `.then` continuation, and (d) a *persistent
//! pipeline* built once and re-fired per iteration — measuring what the
//! paper's future abstraction costs on top of the request layer, and what
//! the persistent template saves versus re-describing the operation every
//! time (paper §IV extended to persistent operations).

use ferrompi::modern::{Communicator, Pipeline, Source, Tag};
use ferrompi::raw;
use ferrompi::universe::Universe;
use ferrompi::util::stats::mean;

const ITERS: usize = 2000;

fn bench_job(name: &str, f: impl Fn(&ferrompi::comm::Comm, usize) + Send + Sync) -> f64 {
    // 2 ranks, zero-cost network: isolates software path length.
    let times = Universe::test(2).run(|world| {
        // warmup
        f(world, 50);
        let t0 = std::time::Instant::now();
        f(world, ITERS);
        t0.elapsed().as_secs_f64() / ITERS as f64
    });
    let t = mean(&times);
    println!("bench {name:<42} {:>10.0} ns/roundtrip", t * 1e9);
    t
}

fn main() {
    println!("\nA2 — ping-pong roundtrip cost by completion style ({ITERS} iters):\n");

    let raw_t = bench_job("raw: isend/irecv + mpi_waitall", |world, iters| {
        raw::init(world);
        let mut rank = -1;
        raw::mpi_comm_rank(raw::MPI_COMM_WORLD, &mut rank);
        let peer = 1 - rank;
        let payload = [1i32];
        let pb = unsafe { std::slice::from_raw_parts(payload.as_ptr() as *const u8, 4) };
        for _ in 0..iters {
            let mut incoming = [0i32];
            let ib = unsafe { std::slice::from_raw_parts_mut(incoming.as_mut_ptr() as *mut u8, 4) };
            let mut reqs = [raw::MPI_REQUEST_NULL; 2];
            raw::mpi_irecv(ib, 1, raw::MPI_INT, peer, 0, raw::MPI_COMM_WORLD, &mut reqs[0]);
            raw::mpi_isend(pb, 1, raw::MPI_INT, peer, 0, raw::MPI_COMM_WORLD, &mut reqs[1]);
            let mut sts = [raw::MpiStatus::default(); 2];
            raw::mpi_waitall(&mut reqs, &mut sts);
        }
        raw::finalize();
    });

    let req_t = bench_job("modern: requests + wait_all", |world, iters| {
        let comm = Communicator::world(world);
        let peer = 1 - comm.rank();
        let dt = <i32 as ferrompi::modern::DataType>::datatype();
        for _ in 0..iters {
            let payload = [1i32];
            let mut incoming = [0i32];
            let pb = unsafe { std::slice::from_raw_parts(payload.as_ptr() as *const u8, 4) };
            let ib = unsafe { std::slice::from_raw_parts_mut(incoming.as_mut_ptr() as *mut u8, 4) };
            let r = comm.native().irecv(ib, 1, &dt, peer as i32, 0).unwrap();
            let s = comm.native().isend(pb, 1, &dt, peer as i32, 0).unwrap();
            ferrompi::request::wait_all(&[r, s]).unwrap();
        }
    });

    let fut_t = bench_job("modern: futures + .then continuation", |world, iters| {
        let comm = Communicator::world(world);
        let peer = 1 - comm.rank();
        for _ in 0..iters {
            let send = comm.immediate_send(&1i32, peer, 0).unwrap();
            let recv = comm.immediate_receive::<i32>(Source::Rank(peer), Tag::Value(0)).unwrap();
            recv.then(move |f| {
                let _ = f.get();
                send
            })
            .get()
            .unwrap();
        }
    });

    let pers_t = bench_job("modern: persistent pipeline (built once)", |world, iters| {
        let comm = Communicator::world(world);
        let peer = 1 - comm.rank();
        // Build phase — not on the timed path conceptually, but cheap and
        // amortized over every warmup+timed iteration anyway.
        let send = comm.persistent_send::<i32>(1, peer, 0).unwrap();
        let recv = comm.persistent_receive::<i32>(1, Source::Rank(peer), Tag::Value(0)).unwrap();
        send.write(&[1]);
        let pipe = Pipeline::join(vec![recv.pipeline(), send.pipeline()]);
        for _ in 0..iters {
            // One MPI_Startall + completion chain; no buffer, datatype or
            // continuation allocation per iteration.
            pipe.run().unwrap();
        }
    });

    let rma_t = bench_job("modern: async RMA put→get future chain", |world, iters| {
        use ferrompi::modern::RmaWindow;
        let win: RmaWindow<i32> = RmaWindow::allocate(world, 1).unwrap();
        win.fence().unwrap();
        let peer = 1 - world.rank();
        for i in 0..iters {
            // One remote write + readback, sequenced as a future chain —
            // two Rma packets + two acks on pooled buffers, no rendezvous.
            let put = win.put_async(&(i as i32), peer, 0);
            let get = win.get_async(peer, 0);
            let v = put
                .then(move |p| {
                    p.get().unwrap();
                    get
                })
                .get()
                .unwrap();
            std::hint::black_box(v);
        }
        win.fence().unwrap();
        win.free().unwrap();
    });

    println!(
        "\nratios: requests/raw = {:.3}, futures/raw = {:.3}, futures/requests = {:.3}, persistent/raw = {:.3}, persistent/futures = {:.3}, rma-chain/raw = {:.3}",
        req_t / raw_t,
        fut_t / raw_t,
        fut_t / req_t,
        pers_t / raw_t,
        pers_t / fut_t,
        rma_t / raw_t
    );
}
