//! Compile-time aggregate reflection for `ferrompi`.
//!
//! This crate is the analog of the paper's use of Boost.PFR: the C++20
//! interface generates MPI datatypes from user-defined aggregate classes at
//! compile time. In Rust the idiomatic mechanism is a derive macro:
//!
//! ```ignore
//! #[derive(Clone, Copy, DataType)]
//! struct Particle {
//!     position: [f32; 3],
//!     velocity: [f32; 3],
//!     id: u64,
//! }
//! // `Particle` now satisfies the `compliant` concept analog and can be
//! // used directly in communication, exactly like Listing 1 of the paper.
//! ```
//!
//! The macro walks the fields of the struct and emits a
//! [`ferrompi::modern::datatype::DataType`] implementation whose typemap is
//! assembled from the field typemaps and `core::mem::offset_of!` offsets, so
//! padding and alignment are captured exactly as the MPI struct-datatype
//! constructor would.

use proc_macro::TokenStream;
use quote::quote;
use syn::{parse_macro_input, Data, DeriveInput, Fields, Index};

/// Derives `ferrompi::modern::datatype::DataType` for a struct whose fields
/// all implement `DataType` themselves (the `mpi::compliant` concept of the
/// paper: arithmetic types, enums-with-repr via manual impl, `[T; N]`,
/// tuples, `Complex<T>`, and nested derived aggregates).
///
/// Compile-time errors are produced for enums, unions, generic structs and
/// zero-field structs, mirroring PFR's "simple aggregate" constraints.
#[proc_macro_derive(DataType)]
pub fn derive_datatype(input: TokenStream) -> TokenStream {
    let input = parse_macro_input!(input as DeriveInput);
    let name = &input.ident;

    if !input.generics.params.is_empty() {
        return syn::Error::new_spanned(
            &input.generics,
            "#[derive(DataType)] does not support generic types \
             (the aggregate must have a single concrete layout)",
        )
        .to_compile_error()
        .into();
    }

    let fields = match &input.data {
        Data::Struct(s) => match &s.fields {
            Fields::Named(f) => f
                .named
                .iter()
                .map(|f| (f.ident.clone().unwrap().into_token_stream2(), f.ty.clone()))
                .collect::<Vec<_>>(),
            Fields::Unnamed(f) => f
                .unnamed
                .iter()
                .enumerate()
                .map(|(i, f)| {
                    let idx = Index::from(i);
                    (quote!(#idx), f.ty.clone())
                })
                .collect::<Vec<_>>(),
            Fields::Unit => {
                return syn::Error::new_spanned(
                    name,
                    "#[derive(DataType)] requires at least one field",
                )
                .to_compile_error()
                .into();
            }
        },
        _ => {
            return syn::Error::new_spanned(
                name,
                "#[derive(DataType)] only supports structs (aggregates); \
                 implement `DataType` manually for enums with a fixed repr",
            )
            .to_compile_error()
            .into();
        }
    };

    let entries = fields.iter().map(|(accessor, ty)| {
        quote! {
            (
                ::core::mem::offset_of!(#name, #accessor) as isize,
                <#ty as ::ferrompi::modern::datatype::DataType>::typemap(),
            )
        }
    });

    let expanded = quote! {
        unsafe impl ::ferrompi::modern::datatype::DataType for #name {
            fn typemap() -> ::ferrompi::datatype::TypeMap {
                ::ferrompi::datatype::TypeMap::aggregate(
                    &[ #( #entries ),* ],
                    ::core::mem::size_of::<#name>(),
                )
            }
        }
    };
    expanded.into()
}

/// Small helper: turn an ident into a token stream (kept local to avoid a
/// trait import at the call site above).
trait IntoTokens2 {
    fn into_token_stream2(self) -> proc_macro2::TokenStream;
}

impl IntoTokens2 for syn::Ident {
    fn into_token_stream2(self) -> proc_macro2::TokenStream {
        quote!(#self)
    }
}
