//! Compile-time aggregate reflection for `ferrompi`.
//!
//! This crate is the analog of the paper's use of Boost.PFR: the C++20
//! interface generates MPI datatypes from user-defined aggregate classes at
//! compile time (Listing 1, the `mpi::compliant` concept). In Rust the
//! idiomatic mechanism is a derive macro:
//!
//! ```
//! use ferrompi::DataType; // one import: the trait *and* the derive macro
//!
//! #[derive(Clone, Copy, DataType)]
//! struct Particle {
//!     position: [f32; 3],
//!     velocity: [f32; 3],
//!     id: u64,
//! }
//!
//! // `Particle` now satisfies the `compliant` concept analog and can be
//! // used directly in communication, exactly like Listing 1 of the paper.
//! let map = Particle::typemap();
//! assert_eq!(map.size(), 32); // 3·f32 + 3·f32 + u64 wire bytes
//! assert_eq!(map.extent() as usize, std::mem::size_of::<Particle>());
//! // Fully dense (no padding): the canonicalized typemap is contiguous,
//! // so sends of `Particle` ride the zero-copy eager/RMA fast path.
//! assert!(map.is_contiguous());
//! ```
//!
//! The macro walks the fields of the struct and emits a
//! [`ferrompi::modern::datatype::DataType`] implementation whose typemap is
//! assembled from the field typemaps and `core::mem::offset_of!` offsets, so
//! padding and alignment are captured exactly as the MPI struct-datatype
//! constructor would. `TypeMap::aggregate` canonicalizes the entries to
//! memory order — `repr(Rust)` is free to reorder fields, and memory order
//! is what lets a fully-dense aggregate take the contiguous memcpy path.
//!
//! # Generics
//!
//! Generic structs are supported; every type parameter gets an auto-added
//! `DataType` bound (the serde convention):
//!
//! ```
//! use ferrompi::DataType;
//!
//! #[derive(Clone, Copy, DataType)]
//! struct Pair<T, const N: usize> {
//!     key: u64,
//!     val: [T; N],
//! }
//!
//! assert_eq!(Pair::<f32, 2>::typemap().size(), 16);
//! ```
//!
//! Lifetime parameters are rejected: references are not plain old data.
//!
//! # `#[mpi(skip)]`
//!
//! A field marked `#[mpi(skip)]` is excluded from the typemap but still
//! covered by the aggregate extent — on the wire it behaves as named
//! padding. The receiver's skipped field keeps its local value:
//!
//! ```
//! use ferrompi::DataType;
//!
//! #[derive(Clone, Copy, DataType)]
//! struct Tracked {
//!     payload: [f64; 4],
//!     #[mpi(skip)]
//!     local_hits: u32, // never transmitted; must still be Copy + 'static
//! }
//!
//! let map = Tracked::typemap();
//! assert_eq!(map.size(), 32); // skipped field contributes no wire bytes
//! assert_eq!(map.extent() as usize, std::mem::size_of::<Tracked>());
//! assert!(!map.is_contiguous()); // the skip gap forces the pack loop
//! ```
//!
//! # The POD gate
//!
//! An unsound derive must be a compile error, not UB at pack time. The
//! macro emits compile-time assertions that the aggregate and every field
//! (skipped or not) are `Copy + 'static` — which structurally rules out
//! drop glue, borrows and interior pointers — plus, for non-generic
//! aggregates, a `const` assertion that `needs_drop::<T>()` is false.
//! Enums, unions, and zero-field structs of every flavor (`struct S;`,
//! `struct S {}`, `struct S();`) are rejected with a spanned error; the
//! trybuild suite in `tests/ui/` pins every macro-emitted diagnostic.
//!
//! The rustc-emitted halves of the gate are asserted here as
//! `compile_fail` doctests (their prose belongs to the compiler, so the
//! UI suite does not snapshot it). A non-`Copy` field:
//!
//! ```compile_fail
//! #[derive(Clone, ferrompi::DataType)]
//! struct Holder {
//!     name: String, // not Copy, not compliant — refused at compile time
//! }
//! ```
//!
//! A forgotten `Copy` on the aggregate itself:
//!
//! ```compile_fail
//! #[derive(Clone, ferrompi::DataType)]
//! struct NoCopy {
//!     x: [f64; 2],
//! }
//! ```
//!
//! And a generic aggregate instantiated with a non-compliant parameter —
//! the auto-added `T: DataType` bound refuses it at the use site:
//!
//! ```compile_fail
//! #[derive(Clone, Copy, ferrompi::DataType)]
//! struct Pair<T> {
//!     a: T,
//!     b: T,
//! }
//! let _ = <Pair<String> as ferrompi::modern::DataType>::typemap();
//! ```

use proc_macro::TokenStream;
use proc_macro2::TokenStream as TokenStream2;
use quote::{quote, quote_spanned};
use syn::spanned::Spanned;
use syn::{parse_macro_input, parse_quote, Data, DeriveInput, Fields, GenericParam, Index, Member};

/// Derives `ferrompi::modern::datatype::DataType` for a struct whose
/// non-skipped fields all implement `DataType` themselves (the
/// `mpi::compliant` concept of the paper: arithmetic types, `[T; N]`,
/// tuples, `Complex<T>`, nested derived aggregates, and enums-with-repr
/// via manual impl).
///
/// See the [crate docs](crate) for the full contract: auto-bounded
/// generics, `#[mpi(skip)]` named padding, and the compile-time POD gate.
#[proc_macro_derive(DataType, attributes(mpi))]
pub fn derive_datatype(input: TokenStream) -> TokenStream {
    let input = parse_macro_input!(input as DeriveInput);
    expand(input).unwrap_or_else(|e| e.to_compile_error()).into()
}

fn expand(input: DeriveInput) -> Result<TokenStream2, syn::Error> {
    let name = &input.ident;

    // `#[mpi(...)]` is a field attribute; on the container it is misuse.
    if let Some(attr) = input.attrs.iter().find(|a| a.path().is_ident("mpi")) {
        return Err(syn::Error::new_spanned(
            attr,
            "#[mpi(...)] is a field attribute; place it on a field, not the struct",
        ));
    }

    if let Some(lt) = input.generics.lifetimes().next() {
        return Err(syn::Error::new_spanned(
            lt,
            "#[derive(DataType)] does not support lifetime parameters: \
             references are not plain old data and cannot be packed",
        ));
    }

    let fields = match &input.data {
        Data::Struct(s) => match &s.fields {
            Fields::Named(f) => f.named.iter().collect::<Vec<_>>(),
            Fields::Unnamed(f) => f.unnamed.iter().collect::<Vec<_>>(),
            Fields::Unit => Vec::new(),
        },
        _ => {
            return Err(syn::Error::new_spanned(
                name,
                "#[derive(DataType)] only supports structs (aggregates); \
                 implement `DataType` manually for enums with a fixed repr",
            ));
        }
    };

    // Zero-field structs of every flavor — `struct S;`, `struct S {}`,
    // `struct S();` — have an empty typemap, which `TypeMap::aggregate`
    // rejects at runtime; make it a compile error here instead.
    if fields.is_empty() {
        return Err(syn::Error::new_spanned(
            name,
            "#[derive(DataType)] requires at least one field: \
             a zero-field struct has an empty typemap and nothing to send",
        ));
    }

    // Partition wire fields from `#[mpi(skip)]` named padding.
    let mut wire: Vec<(Member, &syn::Type)> = Vec::new();
    let mut skipped: Vec<&syn::Type> = Vec::new();
    for (i, field) in fields.iter().enumerate() {
        let accessor = match &field.ident {
            Some(id) => Member::Named(id.clone()),
            None => Member::Unnamed(Index::from(i)),
        };
        if field_is_skipped(field)? {
            skipped.push(&field.ty);
        } else {
            wire.push((accessor, &field.ty));
        }
    }
    if wire.is_empty() {
        return Err(syn::Error::new_spanned(
            name,
            "#[derive(DataType)] requires at least one non-skipped field: \
             marking every field #[mpi(skip)] leaves an empty typemap",
        ));
    }

    // Auto-add `T: DataType` bounds to every type parameter (the serde
    // convention), so generic aggregates work without explicit bounds.
    let mut generics = input.generics.clone();
    for param in &mut generics.params {
        if let GenericParam::Type(tp) = param {
            tp.bounds.push(parse_quote!(::ferrompi::modern::datatype::DataType));
        }
    }
    let (impl_generics, ty_generics, where_clause) = generics.split_for_impl();

    // ---- the POD gate: unsound derives are compile errors ----
    // Per-field compliance/POD checks are spanned to the field type, so
    // the error points at the offending declaration.
    let field_gates = wire.iter().map(|(_, ty)| {
        quote_spanned! {ty.span()=>
            __ferrompi_compliant::<#ty>();
        }
    });
    let skip_gates = skipped.iter().map(|ty| {
        quote_spanned! {ty.span()=>
            __ferrompi_pod::<#ty>();
        }
    });
    let struct_gate = quote_spanned! {name.span()=>
        __ferrompi_pod::<#name #ty_generics>();
    };
    // `Copy` structurally excludes drop glue, but for concrete aggregates
    // we also pin it with an eager const assertion (generic aggregates
    // can't name their parameters in a top-level const; their `Copy`
    // bound carries the same guarantee).
    let no_drop_assert = if input.generics.params.is_empty() {
        quote! {
            const _: () = ::core::assert!(
                !::core::mem::needs_drop::<#name>(),
                "#[derive(DataType)] aggregates must be plain old data (no drop glue)",
            );
        }
    } else {
        TokenStream2::new()
    };

    let entries = wire.iter().map(|(accessor, ty)| {
        quote! {
            (
                ::core::mem::offset_of!(Self, #accessor) as isize,
                <#ty as ::ferrompi::modern::datatype::DataType>::typemap(),
            )
        }
    });

    Ok(quote! {
        const _: () = {
            // Compile-time POD gate (see crate docs): the aggregate and
            // every skipped field must be Copy + 'static; every wire
            // field must itself be `DataType`-compliant.
            fn __ferrompi_compliant<__F: ::ferrompi::modern::datatype::DataType>() {}
            fn __ferrompi_pod<__F: ::core::marker::Copy + 'static>() {}
            #[allow(dead_code)]
            fn __ferrompi_pod_gate #impl_generics () #where_clause {
                #struct_gate
                #(#field_gates)*
                #(#skip_gates)*
            }

            #[automatically_derived]
            unsafe impl #impl_generics ::ferrompi::modern::datatype::DataType
                for #name #ty_generics #where_clause
            {
                fn typemap() -> ::ferrompi::datatype::TypeMap {
                    ::ferrompi::datatype::TypeMap::aggregate(
                        &[ #( #entries ),* ],
                        ::core::mem::size_of::<#name #ty_generics>(),
                    )
                }
            }
        };
        #no_drop_assert
    })
}

/// Parse a field's `#[mpi(...)]` attributes. Currently the only option is
/// `skip`; anything else is a spanned error so typos can't silently widen
/// the wire format.
fn field_is_skipped(field: &syn::Field) -> Result<bool, syn::Error> {
    let mut skip = false;
    for attr in &field.attrs {
        if !attr.path().is_ident("mpi") {
            continue;
        }
        attr.parse_nested_meta(|meta| {
            if meta.path.is_ident("skip") {
                if !meta.input.is_empty() && !meta.input.peek(syn::Token![,]) {
                    return Err(syn::Error::new_spanned(
                        &meta.path,
                        "#[mpi(skip)] takes no arguments",
                    ));
                }
                skip = true;
                Ok(())
            } else {
                Err(syn::Error::new_spanned(
                    &meta.path,
                    "unknown #[mpi(...)] option (supported: `skip`)",
                ))
            }
        })?;
    }
    Ok(skip)
}
