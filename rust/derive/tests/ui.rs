//! trybuild UI suite: every *macro-emitted* diagnostic of
//! `#[derive(DataType)]` is pinned — message and span — so the error UX
//! ossifies (enum/union, every zero-field struct flavor, all-fields
//! skipped, lifetime parameters, `#[mpi(...)]` misuse). The rustc-emitted
//! halves of the POD gate (non-`Copy` field, forgotten `Copy` on the
//! aggregate, generic instantiated with a non-compliant parameter) are
//! asserted as `compile_fail` doctests in `src/lib.rs` instead: their
//! prose belongs to the compiler and would couple these snapshots to the
//! toolchain.
//!
//! Env-gated: the `.stderr` snapshots were seeded without a local
//! toolchain, so the default `cargo test` path skips the suite; CI runs
//! it with `FERROMPI_UI=1` (refresh drifted snapshots locally with
//! `TRYBUILD=overwrite FERROMPI_UI=1 cargo test -p ferrompi-derive --test ui`).

#[test]
fn ui() {
    if std::env::var_os("FERROMPI_UI").is_none() {
        eprintln!("skipping #[derive(DataType)] UI suite; set FERROMPI_UI=1 to run it");
        return;
    }
    let t = trybuild::TestCases::new();
    // The happy path must keep compiling: generics with auto-added
    // bounds, const parameters, tuple structs, nested aggregates and
    // #[mpi(skip)] named padding.
    t.pass("tests/ui/derive_ok.rs");
    // Non-aggregate inputs.
    t.compile_fail("tests/ui/enum.rs");
    t.compile_fail("tests/ui/union.rs");
    // Zero-field structs of every flavor: unit, empty braced, empty tuple.
    t.compile_fail("tests/ui/unit_struct.rs");
    t.compile_fail("tests/ui/empty_braced.rs");
    t.compile_fail("tests/ui/empty_tuple.rs");
    // Skip semantics: an all-skipped struct has an empty typemap.
    t.compile_fail("tests/ui/all_skipped.rs");
    // References are not plain old data.
    t.compile_fail("tests/ui/lifetime_param.rs");
    // #[mpi(...)] misuse: container-level, unknown option, arguments.
    t.compile_fail("tests/ui/mpi_on_struct.rs");
    t.compile_fail("tests/ui/mpi_unknown_option.rs");
    t.compile_fail("tests/ui/mpi_skip_args.rs");
}
