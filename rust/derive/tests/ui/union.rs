#[derive(Clone, Copy, ferrompi::DataType)]
union Raw {
    a: u32,
    b: f32,
}

fn main() {}
