// The happy path: tuple structs, nested derived aggregates, generics
// with auto-added `DataType` bounds, const parameters, and #[mpi(skip)]
// named padding all compile and produce layout-exact typemaps.

#[derive(Clone, Copy, ferrompi::DataType)]
struct Inner(u32, u64);

#[derive(Clone, Copy, ferrompi::DataType)]
struct Outer<T, const N: usize> {
    inner: Inner,
    vals: [T; N],
    pair: (i16, f32),
    #[mpi(skip)]
    scratch: i64,
}

fn main() {
    use ferrompi::modern::DataType;
    let map = Outer::<f64, 3>::typemap();
    assert_eq!(map.extent() as usize, std::mem::size_of::<Outer<f64, 3>>());
    // inner (4 + 8) + vals (3 × 8) + pair (2 + 4); the skip contributes 0.
    assert_eq!(map.size(), 12 + 24 + 6);
    // Padded tuple struct: wire size 12 inside a 16-byte extent.
    let inner = Inner::typemap();
    assert_eq!(inner.size(), 12);
    assert_eq!(inner.extent() as usize, std::mem::size_of::<Inner>());
}
