#[derive(Clone, Copy, ferrompi::DataType)]
struct Packed {
    #[mpi(skip(now))]
    x: u32,
}

fn main() {}
