#[derive(Clone, Copy, ferrompi::DataType)]
enum Kind {
    A,
    B,
}

fn main() {}
