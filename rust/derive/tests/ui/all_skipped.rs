#[derive(Clone, Copy, ferrompi::DataType)]
struct AllPadding {
    #[mpi(skip)]
    a: u32,
    #[mpi(skip)]
    b: u64,
}

fn main() {}
