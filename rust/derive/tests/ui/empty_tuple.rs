#[derive(Clone, Copy, ferrompi::DataType)]
struct Empty();

fn main() {}
