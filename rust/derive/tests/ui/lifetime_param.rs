#[derive(Clone, Copy, ferrompi::DataType)]
struct Borrowed<'a> {
    data: &'a [f32; 4],
}

fn main() {}
