#[derive(Clone, Copy, ferrompi::DataType)]
#[mpi(skip)]
struct Tagged {
    x: u32,
}

fn main() {}
