#[derive(Clone, Copy, ferrompi::DataType)]
struct Packed {
    #[mpi(pack)]
    x: u32,
}

fn main() {}
