"""L1 correctness: Pallas kernels (interpret mode) vs pure-jnp oracles.

The CORE correctness signal for the compute layer — hypothesis sweeps
values (shapes are artifact-fixed by design) including negatives, zeros,
denormal-ish magnitudes, infs and ties.
"""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax.numpy as jnp

from compile.kernels import combine, ref, stencil

BLOCK = combine.BLOCK


def block_of(values):
    """Tile arbitrary-length data to one (BLOCK,) f32 payload."""
    a = np.asarray(values, dtype=np.float32)
    if a.size == 0:
        a = np.zeros(1, dtype=np.float32)
    reps = -(-BLOCK // a.size)
    return jnp.asarray(np.tile(a, reps)[:BLOCK])


finite = st.floats(
    min_value=-1e6, max_value=1e6, allow_nan=False, allow_subnormal=False, width=32
)


@pytest.mark.parametrize("op", combine.OPS)
def test_combine_matches_ref_simple(op):
    rng = np.random.default_rng(42)
    x = jnp.asarray(rng.standard_normal(BLOCK).astype(np.float32))
    y = jnp.asarray(rng.standard_normal(BLOCK).astype(np.float32))
    got = combine.combine(op, x, y)
    want = ref.combine_ref(op, x, y)
    np.testing.assert_allclose(got, want, rtol=1e-6)


@pytest.mark.parametrize("op", combine.OPS)
@settings(max_examples=25, deadline=None)
@given(data=st.lists(finite, min_size=1, max_size=64), seed=st.integers(0, 2**31 - 1))
def test_combine_hypothesis_values(op, data, seed):
    rng = np.random.default_rng(seed)
    x = block_of(data)
    y = jnp.asarray(rng.uniform(-1e6, 1e6, BLOCK).astype(np.float32))
    got = combine.combine(op, x, y)
    want = ref.combine_ref(op, x, y)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-30)


@pytest.mark.parametrize("op", ["max", "min"])
def test_combine_inf_and_ties(op):
    x = block_of([np.inf, -np.inf, 0.0, -0.0, 1.0])
    y = block_of([1.0, 1.0, 0.0, 0.0, 1.0])
    got = combine.combine(op, x, y)
    want = ref.combine_ref(op, x, y)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_combine_sum_commutes():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(BLOCK).astype(np.float32))
    y = jnp.asarray(rng.standard_normal(BLOCK).astype(np.float32))
    np.testing.assert_array_equal(
        np.asarray(combine.combine("sum", x, y)), np.asarray(combine.combine("sum", y, x))
    )


def test_combine_rejects_bad_shapes_and_ops():
    x = jnp.zeros((BLOCK,), jnp.float32)
    with pytest.raises(ValueError):
        combine.combine("sum", x[:-1], x)
    with pytest.raises(ValueError):
        combine.combine("median", x, x)


def test_heat_step_matches_ref():
    rng = np.random.default_rng(7)
    u = jnp.asarray(rng.uniform(0, 100, (stencil.N + 2, stencil.N + 2)).astype(np.float32))
    got = stencil.heat_step(u)
    want = ref.heat_step_ref(u)
    assert got.shape == (stencil.N, stencil.N)
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_heat_step_uniform_field_fixed_point():
    u = jnp.full((stencil.N + 2, stencil.N + 2), 3.5, jnp.float32)
    got = stencil.heat_step(u)
    np.testing.assert_allclose(got, 3.5, rtol=1e-7)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_heat_step_hypothesis(seed):
    rng = np.random.default_rng(seed)
    u = jnp.asarray(rng.standard_normal((stencil.N + 2, stencil.N + 2)).astype(np.float32))
    np.testing.assert_allclose(stencil.heat_step(u), ref.heat_step_ref(u), rtol=1e-5, atol=1e-6)


def test_heat_step_diffusion_smooths():
    # A hot spike must spread: the max decreases, the neighbors warm up.
    u = np.zeros((stencil.N + 2, stencil.N + 2), np.float32)
    c = stencil.N // 2
    u[c + 1, c + 1] = 100.0
    out = np.asarray(stencil.heat_step(jnp.asarray(u)))
    assert out[c, c] < 100.0
    assert out[c - 1, c] > 0.0
    assert out[c, c + 1] > 0.0
