"""Satellite: identity padding for payloads that are not a multiple of
the fixed 4096-element combine block.

The AOT artifacts are compiled for exactly (BLOCK,) operands, so the
rust chunking seam pads tail chunks with the op identity and trims the
result. These tests pin that contract from the python side:
``combine_padded`` over ragged lengths must match the pure-jnp oracle
exactly, and the pad lanes must be invisible in the output.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from compile.kernels import combine, ref

BLOCK = combine.BLOCK

# Ragged lengths around the block boundary: sub-block, off-by-one both
# sides of one and several blocks, and a multi-block ragged tail.
RAGGED = (1, 7, BLOCK - 1, BLOCK + 1, 2 * BLOCK - 17, 3 * BLOCK + 4096 - 1, 16401)


def payloads(n, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.uniform(-1e3, 1e3, n).astype(np.float32))
    y = jnp.asarray(rng.uniform(-1e3, 1e3, n).astype(np.float32))
    return x, y


@pytest.mark.parametrize("op", combine.OPS)
@pytest.mark.parametrize("n", RAGGED)
def test_padded_combine_matches_ref_on_ragged_lengths(op, n):
    x, y = payloads(n, seed=n)
    got = combine.combine_padded(op, x, y)
    assert got.shape == (n,)
    want = ref.combine_ref(op, x, y)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("op", combine.OPS)
def test_identity_element_is_neutral(op):
    """x OP identity == x for every op — the property padding relies on."""
    x, _ = payloads(257, seed=3)
    ident = jnp.full_like(x, combine.IDENTITY[op])
    got = combine.combine_padded(op, x, ident)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(x))


@pytest.mark.parametrize("op", combine.OPS)
def test_block_multiple_lengths_need_no_padding(op):
    """Exact multiples go through unchanged (no concat/trim artifacts)."""
    x, y = payloads(2 * BLOCK, seed=11)
    got = combine.combine_padded(op, x, y)
    want = ref.combine_ref(op, x, y)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_padded_combine_rejects_bad_inputs():
    x, y = payloads(10)
    with pytest.raises(ValueError):
        combine.combine_padded("median", x, y)
    with pytest.raises(ValueError):
        combine.combine_padded("sum", x[:-1], y)
