"""L2 checks: model functions trace, shapes/dtypes are stable, the fused
step agrees with its unfused parts."""

import numpy as np
import jax
import jax.numpy as jnp

from compile import model
from compile.kernels import ref


def test_artifact_specs_cover_expected_names():
    names = set(model.artifact_specs())
    assert {"combine_sum_f32", "combine_prod_f32", "combine_max_f32", "combine_min_f32"} <= names
    assert {"heat_step_f32", "heat_step_fused_f32"} <= names


def test_all_specs_trace_and_return_tuples():
    for name, (fn, args) in model.artifact_specs().items():
        out_shape = jax.eval_shape(fn, *args)
        assert isinstance(out_shape, tuple), name
        for leaf in out_shape:
            assert leaf.dtype == jnp.float32, name


def test_combine_fn_executes():
    fn, _ = model.artifact_specs()["combine_sum_f32"]
    x = jnp.arange(model.BLOCK, dtype=jnp.float32)
    y = jnp.ones((model.BLOCK,), jnp.float32)
    (out,) = fn(x, y)
    np.testing.assert_allclose(out, x + 1.0)


def test_fused_heat_step_matches_parts():
    rng = np.random.default_rng(3)
    u = jnp.asarray(rng.uniform(0, 1, (model.TILE + 2, model.TILE + 2)).astype(np.float32))
    new, resid = model.heat_step_fused_fn(u)
    np.testing.assert_allclose(new, ref.heat_step_ref(u), rtol=1e-6)
    expect = np.sum((np.asarray(new) - np.asarray(u)[1:-1, 1:-1]) ** 2)
    np.testing.assert_allclose(resid, expect, rtol=1e-4)
