"""AOT bridge checks: HLO text generation is well-formed and stable."""

import pathlib
import subprocess
import sys

import pytest

from compile import aot, model


@pytest.mark.parametrize("name", ["combine_sum_f32", "heat_step_f32"])
def test_hlo_text_wellformed(name):
    fn, args = model.artifact_specs()[name]
    text = aot.to_hlo_text(fn, args)
    assert "ENTRY" in text
    assert "HloModule" in text
    # return_tuple=True -> the root computation returns a tuple.
    assert "tuple" in text.lower()


def test_hlo_text_deterministic():
    fn, args = model.artifact_specs()["combine_max_f32"]
    assert aot.to_hlo_text(fn, args) == aot.to_hlo_text(fn, args)


def test_cli_writes_artifacts(tmp_path):
    rc = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(tmp_path), "--only", "combine_min_f32"],
        cwd=pathlib.Path(__file__).resolve().parents[1],
        capture_output=True,
        text=True,
    )
    assert rc.returncode == 0, rc.stderr
    out = tmp_path / "combine_min_f32.hlo.txt"
    assert out.exists()
    assert "ENTRY" in out.read_text()
    manifest = (tmp_path / "MANIFEST.txt").read_text()
    assert "combine_min_f32.hlo.txt" in manifest
