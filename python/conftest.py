"""Make the `compile` package importable when pytest is invoked from the
repository root (CI runs `python -m pytest python/tests`)."""

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))
