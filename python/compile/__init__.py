"""Build-time compile path (L1 kernels + L2 model + AOT lowering).

Never imported at runtime: `make artifacts` runs this once, the rust
binary loads the resulting HLO text through PJRT.
"""
