"""L2: the JAX compute graphs the rust coordinator executes via PJRT.

Each function here calls the L1 Pallas kernels and is lowered once by
``aot.py`` to HLO text in ``artifacts/``. Python never runs on the request
path: the rust runtime loads these artifacts at startup.
"""

import jax
import jax.numpy as jnp

from .kernels import combine, stencil

BLOCK = combine.BLOCK
TILE = stencil.N


def combine_fn(op: str):
    """One (BLOCK,)-f32 combine step: ``out = x OP y``.

    Returned as a 1-tuple (the AOT bridge lowers with return_tuple=True and
    the rust side unwraps with to_tuple1).
    """

    def fn(x, y):
        return (combine.combine(op, x, y),)

    fn.__name__ = f"combine_{op}"
    return fn


def heat_step_fn(u_padded):
    """One Jacobi step over a padded local tile (see kernels.stencil)."""
    return (stencil.heat_step(u_padded),)


def heat_step_fused_fn(u_padded):
    """Jacobi step fused with the local residual reduction: returns the
    updated interior and sum((u_new - u_old)^2) so the coordinator gets
    both from a single artifact execution (one PJRT call per step instead
    of two)."""
    new = stencil.heat_step(u_padded)
    old = u_padded[1:-1, 1:-1]
    resid = jnp.sum((new - old) ** 2, dtype=jnp.float32)
    return (new, resid)


def artifact_specs():
    """name -> (callable, example args): everything aot.py lowers."""
    f32 = jnp.float32
    block = jax.ShapeDtypeStruct((BLOCK,), f32)
    tile = jax.ShapeDtypeStruct((TILE + 2, TILE + 2), f32)
    specs = {}
    for op in combine.OPS:
        specs[f"combine_{op}_f32"] = (combine_fn(op), (block, block))
    specs["heat_step_f32"] = (heat_step_fn, (tile,))
    specs["heat_step_fused_f32"] = (heat_step_fused_fn, (tile,))
    return specs
