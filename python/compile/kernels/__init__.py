"""L1 Pallas kernels + pure-jnp reference oracles."""

from . import combine, ref, stencil  # noqa: F401
