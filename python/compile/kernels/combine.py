"""L1 Pallas kernels: element-wise reduction combiners.

These are the compute hot-spot of the reproduction: MPI's predefined
reduction operations (SUM/PROD/MAX/MIN) applied block-wise during
``MPI_Reduce``/``MPI_Allreduce``. The rust coordinator executes the
AOT-lowered HLO of these kernels through PJRT as a user-defined MPI op
(``MPI_Op_create``), which is exactly how an accelerator-offloaded
reduction would plug into a real MPI library.

TPU-shape thinking (DESIGN.md §Hardware-Adaptation): the 1-D payload is
viewed as (BLOCK_ROWS, 128) — the VPU lane width — and tiled in
(8, 128)-multiple blocks sized well under VMEM. ``interpret=True`` is
mandatory on this image (CPU PJRT cannot run Mosaic custom-calls); the
lowered HLO is plain elementwise ops, which XLA:CPU vectorizes.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Combine payloads are fixed-shape blocks of BLOCK elements; the rust side
# chunks/pads arbitrary buffers into these.
LANES = 128
BLOCK_ROWS = 32  # 32 x 128 = 4096 elements per block
BLOCK = BLOCK_ROWS * LANES

# Tile: 8 sublanes x 128 lanes, the native f32 VREG tile on TPU.
TILE_ROWS = 8

OPS = ("sum", "prod", "max", "min")

# Identity element per op: tail blocks of a payload that is not a
# multiple of BLOCK are padded with these on the rust side, so the pad
# lanes pass through the combine untouched and can be sliced off. Kept
# here (next to the kernels) so both language sides share one source of
# truth — python/tests/test_combine_padding.py pins the semantics.
IDENTITY = {
    "sum": 0.0,
    "prod": 1.0,
    "max": float("-inf"),
    "min": float("inf"),
}


def _combine_kernel(op):
    def kernel(x_ref, y_ref, o_ref):
        x = x_ref[...]
        y = y_ref[...]
        if op == "sum":
            o_ref[...] = x + y
        elif op == "prod":
            o_ref[...] = x * y
        elif op == "max":
            o_ref[...] = jnp.maximum(x, y)
        elif op == "min":
            o_ref[...] = jnp.minimum(x, y)
        else:  # pragma: no cover - guarded by OPS
            raise ValueError(op)

    return kernel


def combine(op: str, x, y):
    """``out[i] = x[i] OP y[i]`` over one (BLOCK,) f32 payload block.

    The grid walks (TILE_ROWS, LANES) tiles so each invocation touches one
    VREG-aligned tile; VMEM footprint is 3 tiles (x, y, out) = 12 KiB f32.
    """
    if op not in OPS:
        raise ValueError(f"unknown combine op {op!r}")
    if x.shape != (BLOCK,) or y.shape != (BLOCK,):
        raise ValueError(f"combine expects ({BLOCK},) blocks, got {x.shape}/{y.shape}")
    x2 = x.reshape(BLOCK_ROWS, LANES)
    y2 = y.reshape(BLOCK_ROWS, LANES)
    out = pl.pallas_call(
        _combine_kernel(op),
        out_shape=jax.ShapeDtypeStruct((BLOCK_ROWS, LANES), x.dtype),
        grid=(BLOCK_ROWS // TILE_ROWS,),
        in_specs=[
            pl.BlockSpec((TILE_ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec((TILE_ROWS, LANES), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_ROWS, LANES), lambda i: (i, 0)),
        interpret=True,
    )(x2, y2)
    return out.reshape(BLOCK)


def combine_padded(op: str, x, y):
    """``out[i] = x[i] OP y[i]`` over arbitrary-length 1-D f32 payloads.

    The model of the rust chunking seam: payloads whose length is not a
    multiple of ``BLOCK`` are padded up with the op's :data:`IDENTITY`
    element, pushed through the fixed-shape :func:`combine` kernel one
    block at a time, and trimmed back. The kernel itself never sees a
    ragged shape — exactly the AOT contract (artifact shapes are fixed at
    compile time).
    """
    if op not in OPS:
        raise ValueError(f"unknown combine op {op!r}")
    if x.shape != y.shape or x.ndim != 1:
        raise ValueError(f"combine_padded expects matching 1-D payloads, got {x.shape}/{y.shape}")
    n = x.shape[0]
    if n == 0:
        return x
    pad = (-n) % BLOCK
    ident = jnp.asarray(IDENTITY[op], x.dtype)
    xp = jnp.concatenate([x, jnp.full((pad,), ident, x.dtype)]) if pad else x
    yp = jnp.concatenate([y, jnp.full((pad,), ident, y.dtype)]) if pad else y
    blocks = [
        combine(op, xp[b : b + BLOCK], yp[b : b + BLOCK]) for b in range(0, n + pad, BLOCK)
    ]
    return jnp.concatenate(blocks)[:n]
