"""L1 Pallas kernel: 5-point Jacobi heat-diffusion step.

The end-to-end example (examples/heat_stencil.rs) runs a 2-D heat equation
on a 4x4 rank grid; each rank's local tile is (N, N) with a 1-cell halo
exchanged through the modern interface's neighborhood collectives. The
interior update is this kernel, AOT-lowered and executed by the rust
runtime via PJRT.

Tiling: the padded (N+2, N+2) input stays in one VMEM block (N=64 -> 17 KiB
f32); the kernel reads four shifted views and writes the (N, N) interior.
This is the BlockSpec analog of the halo-cell scheme a CUDA implementation
would do with shared-memory tiles.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Local tile edge (without halo).
N = 64
ALPHA = 0.25  # diffusion coefficient * dt / dx^2, stable for Jacobi


def _heat_kernel(u_ref, o_ref):
    u = u_ref[...]
    center = u[1:-1, 1:-1]
    north = u[:-2, 1:-1]
    south = u[2:, 1:-1]
    west = u[1:-1, :-2]
    east = u[1:-1, 2:]
    o_ref[...] = center + ALPHA * (north + south + east + west - 4.0 * center)


@functools.partial(jax.jit, static_argnums=())
def heat_step(u_padded):
    """One Jacobi step: (N+2, N+2) padded tile -> (N, N) updated interior."""
    if u_padded.shape != (N + 2, N + 2):
        raise ValueError(f"heat_step expects ({N + 2}, {N + 2}), got {u_padded.shape}")
    return pl.pallas_call(
        _heat_kernel,
        out_shape=jax.ShapeDtypeStruct((N, N), u_padded.dtype),
        interpret=True,
    )(u_padded)
