"""Pure-jnp correctness oracles for the Pallas kernels.

pytest (python/tests/test_kernel.py) asserts allclose between these and the
interpret-mode Pallas kernels over hypothesis-generated inputs; the rust
integration tests then compare the PJRT-executed AOT artifacts against the
same semantics from the other side of the language boundary.
"""

import jax.numpy as jnp

from . import stencil


def combine_ref(op: str, x, y):
    """Reference element-wise combiner (any shape)."""
    if op == "sum":
        return x + y
    if op == "prod":
        return x * y
    if op == "max":
        return jnp.maximum(x, y)
    if op == "min":
        return jnp.minimum(x, y)
    raise ValueError(f"unknown combine op {op!r}")


def heat_step_ref(u_padded):
    """Reference 5-point Jacobi update: padded tile -> interior."""
    c = u_padded[1:-1, 1:-1]
    lap = (
        u_padded[:-2, 1:-1]
        + u_padded[2:, 1:-1]
        + u_padded[1:-1, :-2]
        + u_padded[1:-1, 2:]
        - 4.0 * c
    )
    return c + stencil.ALPHA * lap
