"""AOT lowering: JAX -> HLO *text* artifacts for the rust runtime.

HLO text (NOT ``lowered.compile()`` or serialized protos) is the
interchange format: jax >= 0.5 emits HloModuleProtos with 64-bit
instruction ids, which the image's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Usage: ``cd python && python -m compile.aot --out ../artifacts``
(idempotent; `make artifacts` skips it when inputs are unchanged).
"""

import argparse
import hashlib
import pathlib
import sys

import jax
from jax._src.lib import xla_client as xc

from .model import artifact_specs


def to_hlo_text(fn, example_args) -> str:
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact output directory")
    ap.add_argument("--only", default=None, help="lower just one artifact by name")
    args = ap.parse_args()
    out = pathlib.Path(args.out)
    out.mkdir(parents=True, exist_ok=True)

    manifest = []
    for name, (fn, example_args) in sorted(artifact_specs().items()):
        if args.only and name != args.only:
            continue
        text = to_hlo_text(fn, example_args)
        path = out / f"{name}.hlo.txt"
        path.write_text(text)
        digest = hashlib.sha256(text.encode()).hexdigest()[:16]
        manifest.append(f"{name}.hlo.txt {len(text)} {digest}")
        print(f"wrote {path} ({len(text)} chars, sha256/16 {digest})")

    (out / "MANIFEST.txt").write_text("\n".join(manifest) + "\n")
    return 0


if __name__ == "__main__":
    sys.exit(main())
